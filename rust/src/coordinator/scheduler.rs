//! The coordinator: a multi-tenant serving tier. Jobs pass §4.5 noise
//! admission plus load/deadline admission, queue per tenant with
//! round-robin fairness, and execute on the in-tree executor's worker
//! lanes (`runtime::exec`) over a shared (batching) engine — each job
//! wrapped in its tenant's [`TenantEngine`] so repeated plaintext
//! operands hit the tenant's byte-budgeted cache. Deadlines ride a
//! timer wheel: a job whose deadline passes while still queued is
//! expired *before* any engine work starts. Completion is signalled
//! per job through a one-shot event, so a waiter wakes O(1) times no
//! matter how many unrelated jobs finish.

use std::collections::{BTreeMap, VecDeque};
use std::path::Path;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::els::encrypted::{self, CheckpointHook, DatasetRef, DescentCheckpoint, EncryptedFit};
use crate::runtime::backend::HeEngine;
use crate::runtime::exec::{Executor, TimerHandle, TimerWheel};
use crate::util::error::{Context, Result};
use crate::util::faults::{self, FaultKind, FaultSite};
use crate::util::telemetry::{self, Phase};

use super::admission::{admit, admit_load, AdmissionRequest, LoadState};
use super::job::{Job, JobId, JobSpec, JobState};
use super::journal::{self, Journal, JournalRecord};
use super::metrics::Metrics;
use super::protocol::{ErrorCode, WireError, WireResult};
use super::tenant::{TenantEngine, TenantId, TenantRegistry};

/// Serving-tier sizing knobs.
#[derive(Clone, Copy, Debug)]
pub struct CoordinatorConfig {
    /// Executor worker lanes (jobs executing concurrently).
    pub lanes: usize,
    /// Pending-queue capacity across all tenants; submissions beyond
    /// this are rejected `Overloaded` instead of growing the queue.
    pub queue_capacity: usize,
    /// Per-tenant operand-cache byte budget.
    pub cache_budget_bytes: usize,
    /// Operand-cache shards per tenant.
    pub cache_shards: usize,
    /// Journal a descent resume point every this many iterations
    /// (0 disables mid-fit checkpoints). Only a journal-backed
    /// coordinator ([`Coordinator::recover`]) checkpoints at all.
    pub checkpoint_every: usize,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        CoordinatorConfig {
            lanes: 4,
            queue_capacity: 64,
            cache_budget_bytes: 8 << 20,
            cache_shards: 4,
            checkpoint_every: 1,
        }
    }
}

/// A queued execution: the spec plus the deadline timer to cancel on
/// pickup, and — for journal-recovered jobs — the checkpoint to
/// resume from instead of starting at iteration one.
struct QueuedJob {
    id: JobId,
    spec: JobSpec,
    timer: Option<TimerHandle>,
    resume: Option<DescentCheckpoint>,
}

/// Per-tenant FIFO queues drained by a rotating round-robin cursor:
/// each pop serves the next tenant with pending work, so a tenant
/// flooding the queue cannot starve another's single job. Generic so
/// the fairness discipline unit-tests without ciphertexts.
pub(crate) struct TenantQueues<T> {
    queues: BTreeMap<TenantId, VecDeque<T>>,
    order: Vec<TenantId>,
    cursor: usize,
    pending: usize,
}

impl<T> Default for TenantQueues<T> {
    fn default() -> Self {
        TenantQueues { queues: BTreeMap::new(), order: Vec::new(), cursor: 0, pending: 0 }
    }
}

impl<T> TenantQueues<T> {
    pub(crate) fn push(&mut self, tenant: &TenantId, entry: T) {
        if !self.queues.contains_key(tenant) {
            self.order.push(tenant.clone());
        }
        self.queues.entry(tenant.clone()).or_default().push_back(entry);
        self.pending += 1;
    }

    pub(crate) fn pop_fair(&mut self) -> Option<T> {
        let n = self.order.len();
        for i in 0..n {
            let idx = (self.cursor + i) % n;
            if let Some(entry) = self.queues.get_mut(&self.order[idx]).and_then(VecDeque::pop_front)
            {
                self.cursor = (idx + 1) % n;
                self.pending -= 1;
                return Some(entry);
            }
        }
        None
    }

    pub(crate) fn pending(&self) -> usize {
        self.pending
    }
}

/// What a drain accomplished: how many queued jobs were bounced
/// (resolved `Cancelled`, no engine work lost) and whether every
/// in-flight job reached a terminal state before the timeout.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct DrainReport {
    pub bounced: u64,
    pub drained: bool,
}

/// What [`Coordinator::recover`] rebuilt from the journal.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct RecoveredCounts {
    /// Accepted-but-unfinished jobs put back on the queue.
    pub requeued: u64,
    /// Of the requeued, how many resume from a journaled checkpoint
    /// instead of restarting at iteration one.
    pub resumed: u64,
    /// Completed-but-unacked results re-served straight from the
    /// journal — zero engine work.
    pub restored: u64,
    /// Failed/expired/bounced-but-unacked jobs restored terminal, so
    /// the client's retry fetches the original structured error.
    pub failed: u64,
}

impl RecoveredCounts {
    /// Total journaled jobs brought back to life (the `recovered`
    /// health field).
    pub fn total(&self) -> u64 {
        self.requeued + self.restored + self.failed
    }
}

/// The job coordinator.
pub struct Coordinator {
    engine: Arc<dyn HeEngine>,
    exec: Executor,
    timers: TimerWheel,
    jobs: Mutex<BTreeMap<JobId, Job>>,
    queue: Mutex<TenantQueues<QueuedJob>>,
    /// Idempotent-submission table: `(tenant, token)` → the job that
    /// submission created. Lock order: `tokens` strictly before
    /// `queue`/`jobs` (token-bearing submits hold it across enqueue so
    /// two racing retries cannot both create a job).
    tokens: Mutex<BTreeMap<(TenantId, String), JobId>>,
    tenants: TenantRegistry,
    running: AtomicUsize,
    /// Flipped false by [`begin_shutdown`](Self::begin_shutdown);
    /// checked under the queue lock so admission and drain serialise.
    accepting: AtomicBool,
    started: Instant,
    next_id: AtomicU64,
    /// Write-ahead journal of lifecycle transitions; `None` for a
    /// non-durable coordinator (`new`/`with_config`). Attached by
    /// [`recover`](Self::recover), which doubles as the journal-enabled
    /// constructor.
    journal: Option<Journal>,
    /// What `recover` rebuilt (all zero for a fresh coordinator).
    recovered: RecoveredCounts,
    cfg: CoordinatorConfig,
    pub metrics: Arc<Metrics>,
}

impl Coordinator {
    /// Default-config coordinator with `lanes` worker lanes (the
    /// pre-serving-tier `max_concurrent` knob).
    pub fn new(engine: Arc<dyn HeEngine>, lanes: usize) -> Arc<Self> {
        Self::with_config(
            engine,
            CoordinatorConfig { lanes: lanes.max(1), ..CoordinatorConfig::default() },
        )
    }

    pub fn with_config(engine: Arc<dyn HeEngine>, cfg: CoordinatorConfig) -> Arc<Self> {
        Self::build(engine, cfg, None, RecoveredCounts::default(), 1)
    }

    /// Open (or create) the journal under `journal_dir` and rebuild
    /// live state from it: queued jobs re-enqueue, in-flight jobs
    /// resume from their last checkpoint, completed-but-unacked
    /// results are re-served from the journal with zero engine work,
    /// and unacked failures stay fetchable as their original
    /// structured errors. Doubles as the journal-enabled constructor —
    /// on an empty directory it recovers nothing and simply journals
    /// from here on.
    ///
    /// Recovered deadlines restart with their full original budget:
    /// the journal records the *requested* `deadline_ms`, and charging
    /// a job for wall-clock the dead process consumed would expire
    /// work the client is still entitled to.
    pub fn recover(
        engine: Arc<dyn HeEngine>,
        cfg: CoordinatorConfig,
        journal_dir: impl AsRef<Path>,
    ) -> Result<Arc<Self>> {
        let (journal, docs) = Journal::open(journal_dir)?;
        let records = docs
            .iter()
            .map(|d| JournalRecord::from_json(engine.ctx(), d))
            .collect::<Result<Vec<_>>>()
            .context("decoding journal records")?;
        let state = journal::replay(records);
        let mut recovered = RecoveredCounts::default();
        for job in state.jobs.values() {
            if job.acked {
                continue;
            }
            if job.fit.is_some() {
                recovered.restored += 1;
            } else if job.failed.is_some() {
                recovered.failed += 1;
            } else {
                recovered.requeued += 1;
                if job.ckpt.is_some() {
                    recovered.resumed += 1;
                }
            }
        }
        let me = Self::build(engine, cfg, Some(journal), recovered, state.max_id + 1);
        for (raw_id, rj) in state.jobs {
            if rj.acked {
                continue;
            }
            let id = JobId(raw_id);
            if let Some(tok) = rj.token.clone() {
                me.tokens.lock().unwrap().insert((rj.tenant.clone(), tok), id);
            }
            if let Some(fit) = rj.fit {
                me.restore_terminal(id, &rj.tenant, JobState::Done(fit));
            } else if let Some((code, message)) = rj.failed {
                let state = match code {
                    ErrorCode::DeadlineExceeded => JobState::Expired,
                    ErrorCode::ShuttingDown => JobState::Cancelled,
                    _ => JobState::Failed(message),
                };
                me.restore_terminal(id, &rj.tenant, state);
            } else {
                me.requeue_recovered(id, rj);
            }
        }
        Ok(me)
    }

    fn build(
        engine: Arc<dyn HeEngine>,
        cfg: CoordinatorConfig,
        journal: Option<Journal>,
        recovered: RecoveredCounts,
        next_id: u64,
    ) -> Arc<Self> {
        Arc::new(Coordinator {
            engine,
            exec: Executor::new("els-coord", cfg.lanes.max(1)),
            timers: TimerWheel::new("els-coord", Duration::from_millis(5)),
            jobs: Mutex::new(BTreeMap::new()),
            queue: Mutex::new(TenantQueues::default()),
            tokens: Mutex::new(BTreeMap::new()),
            tenants: TenantRegistry::new(cfg.cache_budget_bytes, cfg.cache_shards),
            running: AtomicUsize::new(0),
            accepting: AtomicBool::new(true),
            started: Instant::now(),
            next_id: AtomicU64::new(next_id),
            journal,
            recovered,
            cfg,
            metrics: Arc::new(Metrics::default()),
        })
    }

    /// Re-insert a journaled terminal job (done- or failed-but-
    /// unacked): fetchable immediately, zero engine work.
    fn restore_terminal(&self, id: JobId, tenant: &TenantId, state: JobState) {
        let mut job = Job::new(id, tenant.clone(), None);
        job.state = state;
        job.finished = Some(Instant::now());
        job.done.notify();
        self.jobs.lock().unwrap().insert(id, job);
    }

    /// Put a recovered accepted-but-unfinished job back on the queue,
    /// resuming from its last journaled checkpoint if one survived.
    fn requeue_recovered(self: &Arc<Self>, id: JobId, rj: journal::ReplayJob) {
        let journal::ReplayJob { tenant, token, deadline_ms, cfg, cd_updates, data, ckpt, .. } = rj;
        let mut spec = JobSpec::new(data, cfg, cd_updates).with_tenant(tenant);
        spec.deadline_ms = deadline_ms;
        spec.token = token;
        let deadline = spec.deadline_ms.map(|ms| Instant::now() + Duration::from_millis(ms));
        self.jobs.lock().unwrap().insert(id, Job::new(id, spec.tenant.clone(), deadline));
        let timer = deadline.map(|d| {
            let me = Arc::clone(self);
            self.timers.schedule(d, move || me.expire_if_queued(id))
        });
        let tenant_id = spec.tenant.clone();
        self.queue.lock().unwrap().push(&tenant_id, QueuedJob { id, spec, timer, resume: ckpt });
        let me = Arc::clone(self);
        if !self.exec.spawn(move || me.run_next()) {
            self.cancel_if_queued(id);
        }
    }

    pub fn engine(&self) -> &Arc<dyn HeEngine> {
        &self.engine
    }

    pub fn tenants(&self) -> &TenantRegistry {
        &self.tenants
    }

    pub fn config(&self) -> &CoordinatorConfig {
        &self.cfg
    }

    /// Jobs queued but not yet picked up by a lane.
    pub fn queue_depth(&self) -> usize {
        self.queue.lock().unwrap().pending()
    }

    /// Submit a job. Noise admission (§4.5) and load/deadline
    /// admission run synchronously; on success the fit executes on an
    /// executor lane under the tenant's engine view.
    pub fn submit(self: &Arc<Self>, spec: JobSpec) -> WireResult<JobId> {
        self.metrics.jobs_submitted.fetch_add(1, Ordering::Relaxed);
        // Idempotent replay: a token-bearing submit holds the token
        // table for its whole critical section, so a duplicate either
        // sees the mapping (and re-attaches — no second fit, the ct-mul
        // counter proves it) or is the one that creates it.
        let token_key = spec.token.clone().map(|t| (spec.tenant.clone(), t));
        let mut tokens = token_key.as_ref().map(|_| self.tokens.lock().unwrap());
        if let (Some(key), Some(tokens)) = (token_key.as_ref(), tokens.as_deref()) {
            if let Some(&id) = tokens.get(key) {
                self.metrics.jobs_deduped.fetch_add(1, Ordering::Relaxed);
                return Ok(id);
            }
        }
        let tenant = self.tenants.get_or_create(&spec.tenant);
        tenant.counters.jobs_submitted.fetch_add(1, Ordering::Relaxed);
        let req = AdmissionRequest {
            n_obs: spec.data.n(),
            p_vars: spec.data.p(),
            iters: spec.cfg.iters,
            phi: spec.data.phi,
            nu: spec.cfg.nu,
            accel: spec.cfg.accel,
            cd_updates: spec.cd_updates,
        };
        let admitted = {
            let _span = telemetry::span(Phase::JobAdmit);
            admit(&self.engine.ctx().params, &req)
        };
        if let Err(e) = admitted {
            self.metrics.jobs_rejected.fetch_add(1, Ordering::Relaxed);
            tenant.counters.jobs_rejected.fetch_add(1, Ordering::Relaxed);
            return Err(WireError::new(ErrorCode::AdmissionDenied, e.to_string()));
        }
        // Load/deadline admission under the queue lock, so the
        // capacity check and the enqueue are one atomic step.
        let mut queue = self.queue.lock().unwrap();
        // Drain gate, checked under the same lock `begin_shutdown`
        // holds while bouncing: either this submit queues before the
        // drain sweep (and is bounced by it) or it is refused here —
        // never a job admitted into a draining server unresolved.
        if !self.accepting.load(Ordering::Acquire) {
            self.metrics.jobs_rejected.fetch_add(1, Ordering::Relaxed);
            tenant.counters.jobs_rejected.fetch_add(1, Ordering::Relaxed);
            return Err(WireError::new(
                ErrorCode::ShuttingDown,
                "server is draining; resubmit elsewhere",
            ));
        }
        let load = LoadState {
            pending: queue.pending(),
            running: self.running.load(Ordering::Relaxed),
            lanes: self.cfg.lanes,
            queue_capacity: self.cfg.queue_capacity,
            mean_latency_ms: self.metrics.job_latency.mean_ms(),
        };
        if let Err(e) = admit_load(&load, spec.deadline_ms) {
            match e.code {
                ErrorCode::Overloaded => {
                    self.metrics.jobs_overloaded.fetch_add(1, Ordering::Relaxed)
                }
                _ => self.metrics.jobs_expired.fetch_add(1, Ordering::Relaxed),
            };
            tenant.counters.jobs_rejected.fetch_add(1, Ordering::Relaxed);
            return Err(e);
        }
        let id = JobId(self.next_id.fetch_add(1, Ordering::Relaxed));
        // WAL-first: the `accepted` record must be durable before any
        // state the client could observe exists. A journal that cannot
        // append is a server that cannot promise durability, so the
        // submit bounces retryable instead of taking work it might
        // silently lose. (The fsync runs under the queue lock — that
        // serialises admission behind durability, which is the point.)
        if let Some(journal) = &self.journal {
            if let Err(e) = journal.append_json(&journal::accepted_payload(id, &spec)) {
                self.metrics.jobs_overloaded.fetch_add(1, Ordering::Relaxed);
                tenant.counters.jobs_rejected.fetch_add(1, Ordering::Relaxed);
                return Err(WireError::new(
                    ErrorCode::Overloaded,
                    format!("journal append failed; resubmit: {e}"),
                ));
            }
        }
        let deadline = spec.deadline_ms.map(|ms| Instant::now() + Duration::from_millis(ms));
        let job = Job::new(id, spec.tenant.clone(), deadline);
        self.jobs.lock().unwrap().insert(id, job);
        let timer = deadline.map(|d| {
            let me = Arc::clone(self);
            self.timers.schedule(d, move || me.expire_if_queued(id))
        });
        let tenant_id = spec.tenant.clone();
        queue.push(&tenant_id, QueuedJob { id, spec, timer, resume: None });
        drop(queue);
        if let (Some(key), Some(tokens)) = (token_key, tokens.as_deref_mut()) {
            tokens.insert(key, id);
        }
        // 1:1 invariant: every queued entry gets exactly one lane task,
        // and every lane task pops exactly one entry (possibly finding
        // it already expired). A rejected spawn (executor already shut
        // down — coordinator teardown racing a submit) resolves the job
        // as cancelled instead of leaving a waiter hanging.
        let me = Arc::clone(self);
        if !self.exec.spawn(move || me.run_next()) {
            self.cancel_if_queued(id);
            return Err(WireError::new(
                ErrorCode::ShuttingDown,
                "executor stopped before the job could be scheduled",
            ));
        }
        Ok(id)
    }

    /// Expire `id` if it is still queued (timer-wheel callback; also
    /// the pop-time check's backend). Never touches a running job, and
    /// re-checks the *actual* deadline — a spurious early timer fire
    /// (chaos `timer:spurious`) must not expire a live job.
    fn expire_if_queued(&self, id: JobId) {
        let expired = {
            let mut jobs = self.jobs.lock().unwrap();
            match jobs.get_mut(&id) {
                Some(j)
                    if matches!(j.state, JobState::Queued)
                        && j.deadline.is_some_and(|d| Instant::now() >= d) =>
                {
                    j.state = JobState::Expired;
                    j.finished = Some(Instant::now());
                    self.metrics.jobs_expired.fetch_add(1, Ordering::Relaxed);
                    j.done.notify();
                    true
                }
                _ => false,
            }
        };
        if expired {
            // Terminal record (fail-open, after the lock): recovery
            // must not re-run a job whose client was already told
            // `deadline_exceeded`.
            self.journal_note(&JournalRecord::Failed {
                id,
                code: ErrorCode::DeadlineExceeded,
                message: format!("{id} expired before execution"),
            });
        }
    }

    /// Resolve a still-queued job as `Cancelled` (drain bounce or
    /// failed lane handoff): completes the done-event, counts it, and
    /// never touches a job that reached a lane.
    fn cancel_if_queued(&self, id: JobId) {
        let cancelled = {
            let mut jobs = self.jobs.lock().unwrap();
            match jobs.get_mut(&id) {
                Some(j) if matches!(j.state, JobState::Queued) => {
                    j.state = JobState::Cancelled;
                    j.finished = Some(Instant::now());
                    self.metrics.jobs_cancelled.fetch_add(1, Ordering::Relaxed);
                    j.done.notify();
                    true
                }
                _ => false,
            }
        };
        if cancelled {
            self.journal_note(&JournalRecord::Failed {
                id,
                code: ErrorCode::ShuttingDown,
                message: format!("{id} was bounced by a server drain; resubmit"),
            });
        }
    }

    /// Lane task: pop one queued entry fairly and execute it (or
    /// retire it, if its deadline already passed).
    fn run_next(self: &Arc<Self>) {
        let entry = {
            let _span = telemetry::span(Phase::JobQueue);
            self.queue.lock().unwrap().pop_fair()
        };
        let Some(QueuedJob { id, spec, timer, resume }) = entry else {
            return;
        };
        if let Some(t) = timer {
            t.cancel();
        }
        // Deadline check *before* any engine work: an expired job must
        // never reach the execution phase.
        {
            let mut jobs = self.jobs.lock().unwrap();
            let Some(j) = jobs.get_mut(&id) else { return };
            if !matches!(j.state, JobState::Queued) {
                return; // timer already expired it
            }
            if j.deadline.is_some_and(|d| Instant::now() >= d) {
                drop(jobs);
                self.expire_if_queued(id);
                return;
            }
            j.state = JobState::Running;
        }
        self.running.fetch_add(1, Ordering::Relaxed);
        // Fail-open lifecycle record: losing `started` only means
        // recovery re-queues the job as if no lane had picked it up.
        self.journal_note(&JournalRecord::Started { id });
        if resume.is_some() {
            journal::note_checkpoint_resumed();
        }
        let tenant = self.tenants.get_or_create(&spec.tenant);
        let engine = TenantEngine::new(Arc::clone(&self.engine), Arc::clone(&tenant));
        let ckpt_every = if self.journal.is_some() { self.cfg.checkpoint_every } else { 0 };
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let _span = telemetry::span(Phase::JobExecute);
            // Chaos `lane:panic`: the job dies mid-execution exactly the
            // way a backend bug would — the recovery path below must
            // resolve it to `job_failed` with all state reclaimed.
            if faults::check(FaultSite::Lane) == Some(FaultKind::Panic) {
                panic!("injected lane panic");
            }
            // Journal a resume point every `checkpoint_every`
            // iterations: a crash mid-fit redoes only the tail. A
            // checkpoint that fails to append is dropped, not fatal —
            // the previous one still bounds the redo.
            let mut sink = |ckpt: DescentCheckpoint| {
                if let Some(j) = &self.journal {
                    if j.append(&JournalRecord::Checkpoint { id, ckpt }).is_ok() {
                        journal::note_checkpoint_taken();
                    }
                }
            };
            match spec.cd_updates {
                Some(updates) => {
                    let mut hook = (ckpt_every > 0)
                        .then(|| CheckpointHook { every: ckpt_every, sink: Box::new(&mut sink) });
                    encrypted::fit_cd_with_checkpoints(
                        &engine,
                        &spec.data,
                        spec.cfg.nu,
                        updates,
                        resume.as_ref(),
                        hook.as_mut(),
                    )
                }
                None => {
                    let hook = (ckpt_every > 0)
                        .then(|| CheckpointHook { every: ckpt_every, sink: Box::new(&mut sink) });
                    encrypted::fit_with_checkpoints(
                        &engine,
                        &DatasetRef::Scalar(&spec.data),
                        &spec.cfg,
                        resume.as_ref(),
                        hook,
                    )
                    .map(|outcome| outcome.fit)
                }
            }
        }));
        self.running.fetch_sub(1, Ordering::Relaxed);
        let outcome: std::result::Result<EncryptedFit, String> = match result {
            Ok(Ok(fit)) => Ok(fit),
            Ok(Err(e)) => Err(e.to_string()),
            Err(e) => Err(e
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| e.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "job panicked".to_string())),
        };
        // Journal the outcome *before* publishing it: a `done` a
        // client could observe (and ack) must already be re-servable.
        match &outcome {
            Ok(fit) => {
                if let Some(j) = &self.journal {
                    let _ = j.append_json(&journal::done_payload(id, fit));
                }
            }
            Err(msg) => self.journal_note(&JournalRecord::Failed {
                id,
                code: ErrorCode::JobFailed,
                message: msg.clone(),
            }),
        }
        let mut jobs = self.jobs.lock().unwrap();
        if let Some(j) = jobs.get_mut(&id) {
            j.finished = Some(Instant::now());
            match outcome {
                Ok(fit) => {
                    self.metrics.jobs_completed.fetch_add(1, Ordering::Relaxed);
                    tenant.counters.jobs_completed.fetch_add(1, Ordering::Relaxed);
                    if let Some(lat) = j.latency() {
                        self.metrics.job_latency.observe(lat);
                    }
                    j.state = JobState::Done(fit);
                }
                Err(msg) => {
                    self.metrics.jobs_failed.fetch_add(1, Ordering::Relaxed);
                    j.state = JobState::Failed(msg);
                }
            }
            j.done.notify();
        }
    }

    /// Current state label (None if unknown id).
    pub fn state(&self, id: JobId) -> Option<String> {
        self.jobs.lock().unwrap().get(&id).map(|j| j.state.label().to_string())
    }

    /// How many state inspections `wait` callers have performed on
    /// this job's completion event (O(1)-wakeup diagnostics).
    pub fn event_checks(&self, id: JobId) -> Option<u64> {
        self.jobs.lock().unwrap().get(&id).map(|j| j.done.checks())
    }

    /// When the job reached a terminal state.
    pub fn finished_at(&self, id: JobId) -> Option<Instant> {
        self.jobs.lock().unwrap().get(&id).and_then(|j| j.finished)
    }

    /// Block until the job reaches a terminal state. Waiters park on
    /// the job's own event — completions of other jobs do not wake
    /// them (see `event_checks`).
    pub fn wait(&self, id: JobId, timeout: Duration) -> WireResult<()> {
        let event = match self.jobs.lock().unwrap().get(&id) {
            Some(j) => Arc::clone(&j.done),
            None => return Err(WireError::new(ErrorCode::UnknownJob, format!("unknown {id}"))),
        };
        if event.wait_timeout(timeout) {
            Ok(())
        } else {
            Err(WireError::internal(format!("timeout waiting for {id}")))
        }
    }

    fn terminal_error(id: JobId, state: &JobState) -> WireError {
        match state {
            JobState::Failed(msg) => {
                WireError::new(ErrorCode::JobFailed, format!("job failed: {msg}"))
            }
            JobState::Expired => WireError::new(
                ErrorCode::DeadlineExceeded,
                format!("{id} expired before execution"),
            ),
            JobState::Cancelled => WireError::new(
                ErrorCode::ShuttingDown,
                format!("{id} was bounced by a server drain; resubmit"),
            ),
            _ => unreachable!("terminal_error on non-error state"),
        }
    }

    /// Remove and return a finished fit (in-process consumers: one
    /// shot, the job is forgotten). Wire consumers use the two-step
    /// [`peek_result`](Self::peek_result) + [`release`](Self::release)
    /// so a reply lost in flight can be re-fetched.
    pub fn take_result(&self, id: JobId) -> WireResult<EncryptedFit> {
        let taken = {
            let mut tokens = self.tokens.lock().unwrap();
            let mut jobs = self.jobs.lock().unwrap();
            let terminal = jobs.get(&id).map(|j| j.state.is_terminal());
            match terminal {
                None => {
                    return Err(WireError::new(ErrorCode::UnknownJob, format!("unknown {id}")))
                }
                Some(true) => {
                    let job = jobs.remove(&id).unwrap();
                    tokens.retain(|_, v| *v != id);
                    job
                }
                Some(false) => {
                    let s = jobs.get(&id).unwrap().state.label();
                    return Err(WireError::internal(format!("{id} still {s}")));
                }
            }
        };
        // The job is forgotten in-memory: journal the ack (after the
        // locks, fail-open) so recovery forgets it too.
        self.journal_note(&JournalRecord::Acked { id });
        match taken.state {
            JobState::Done(fit) => Ok(fit),
            other => Err(Self::terminal_error(id, &other)),
        }
    }

    /// Read a finished fit *without* consuming the job — the wire
    /// `result` verb. The job stays tracked until the client `ack`s
    /// ([`release`]), so a reply that dies on the wire (disconnect,
    /// truncated frame) can be re-fetched by a retry instead of
    /// landing on `unknown_job`. At-least-once delivery, zero
    /// recomputation.
    ///
    /// [`release`]: Self::release
    pub fn peek_result(&self, id: JobId) -> WireResult<EncryptedFit> {
        let jobs = self.jobs.lock().unwrap();
        match jobs.get(&id) {
            None => Err(WireError::new(ErrorCode::UnknownJob, format!("unknown {id}"))),
            Some(j) => match &j.state {
                JobState::Done(fit) => Ok(fit.clone()),
                s if s.is_terminal() => Err(Self::terminal_error(id, s)),
                s => Err(WireError::internal(format!("{id} still {}", s.label()))),
            },
        }
    }

    /// Acknowledge a delivered result: forget the terminal job and any
    /// idempotency token pointing at it. Idempotent — acking an
    /// unknown or still-active job is a no-op returning `false`.
    pub fn release(&self, id: JobId) -> bool {
        let released = {
            let mut tokens = self.tokens.lock().unwrap();
            let mut jobs = self.jobs.lock().unwrap();
            match jobs.get(&id) {
                Some(j) if j.state.is_terminal() => {
                    jobs.remove(&id);
                    tokens.retain(|_, v| *v != id);
                    true
                }
                _ => false,
            }
        };
        if released {
            self.journal_note(&JournalRecord::Acked { id });
        }
        released
    }

    // ---- drain / health -------------------------------------------------

    /// Stop admission and bounce every queued job as `Cancelled`.
    /// Running jobs are left to finish (their results stay fetchable).
    /// Idempotent. Timers for bounced jobs are cancelled, their done
    /// events complete — no waiter hangs, no handle leaks.
    pub fn begin_shutdown(&self) {
        let bounced: Vec<QueuedJob> = {
            let mut queue = self.queue.lock().unwrap();
            self.accepting.store(false, Ordering::Release);
            std::iter::from_fn(|| queue.pop_fair()).collect()
        };
        for entry in bounced {
            if let Some(t) = entry.timer {
                t.cancel();
            }
            self.cancel_if_queued(entry.id);
        }
    }

    /// Full drain: [`begin_shutdown`](Self::begin_shutdown), then wait
    /// up to `timeout` for in-flight jobs to reach terminal states.
    pub fn shutdown(&self, timeout: Duration) -> DrainReport {
        let before = self.metrics.jobs_cancelled.load(Ordering::Relaxed);
        self.begin_shutdown();
        let bounced = self.metrics.jobs_cancelled.load(Ordering::Relaxed) - before;
        let deadline = Instant::now() + timeout;
        let drained = loop {
            if self.jobs.lock().unwrap().values().all(|j| j.state.is_terminal()) {
                break true;
            }
            if Instant::now() >= deadline {
                break false;
            }
            std::thread::sleep(Duration::from_millis(2));
        };
        // The final sync of a graceful drain: everything journaled
        // (including the bounce records above) is on disk before the
        // caller tears the process down.
        if let Some(j) = &self.journal {
            let _ = j.sync();
        }
        DrainReport { bounced, drained }
    }

    /// Whether submissions are currently admitted (false once a drain
    /// has begun).
    pub fn is_accepting(&self) -> bool {
        self.accepting.load(Ordering::Acquire)
    }

    /// Time since the coordinator was constructed.
    pub fn uptime(&self) -> Duration {
        self.started.elapsed()
    }

    /// Jobs currently executing on lanes.
    pub fn running_jobs(&self) -> usize {
        self.running.load(Ordering::Relaxed)
    }

    /// Number of executor worker lanes.
    pub fn lanes(&self) -> usize {
        self.exec.lanes()
    }

    /// Jobs tracked (any state) — terminal jobs leave on `release`/
    /// `take_result`, so a steadily growing count means unacked
    /// results.
    pub fn tracked_jobs(&self) -> usize {
        self.jobs.lock().unwrap().len()
    }

    /// Timer-wheel entries currently parked (the chaos battery asserts
    /// this returns to zero — no leaked deadline handles).
    pub fn timers_live(&self) -> usize {
        self.timers.live_entries()
    }

    // ---- durability -----------------------------------------------------

    /// Fail-open append for mid-lifecycle records (`started`,
    /// `failed`, `acked`): the journal already counts the error
    /// (`journal_append_errors`), and the worst case of a lost record
    /// is recovery redoing work the record would have skipped — never
    /// a wrong answer, thanks to token dedup and idempotent replay.
    fn journal_note(&self, rec: &JournalRecord) {
        if let Some(j) = &self.journal {
            let _ = j.append(rec);
        }
    }

    /// The attached journal, if this coordinator is durable.
    pub fn journal(&self) -> Option<&Journal> {
        self.journal.as_ref()
    }

    /// What [`recover`](Self::recover) rebuilt from the journal (all
    /// zero for a coordinator that started fresh).
    pub fn recovered(&self) -> RecoveredCounts {
        self.recovered
    }

    /// Chaos-harness crash simulation: the moral equivalent of
    /// `kill -9` without losing the test process. Journal writes stop
    /// dead — with a deliberately torn record left on disk, the
    /// signature of dying mid-append — the executor drops its ready
    /// queue without running it, and admission closes. Fits already
    /// executing on lanes cannot be preempted; they finish in the
    /// background, but their journal appends no longer land, exactly
    /// like the writes of a dead process. The journal directory is
    /// left ready for [`recover`](Self::recover).
    pub fn crash(&self) {
        self.accepting.store(false, Ordering::Release);
        if let Some(j) = &self.journal {
            j.tear_tail();
        }
        self.exec.abort();
    }
}

#[cfg(test)]
mod tests {
    use std::sync::Arc;

    use super::*;
    use crate::coordinator::batcher::{BatchConfig, BatchingEngine};
    use crate::data::synth;
    use crate::els::encrypted::{decrypt_coefficients, FitConfig};
    use crate::els::exact::{self, QuantisedData};
    use crate::els::float_ref::linf;
    use crate::els::model::encrypt_dataset;
    use crate::els::stepsize::nu_optimal;
    use crate::fhe::keys::keygen;
    use crate::fhe::params::{plan, PlanRequest};
    use crate::fhe::rng::ChaChaRng;
    use crate::fhe::FvContext;
    use crate::runtime::backend::NativeEngine;

    struct Fixture {
        ctx: Arc<FvContext>,
        keys: crate::fhe::KeySet,
        q: QuantisedData,
        nu: u64,
        rng: ChaChaRng,
    }

    fn fixture(seed: u64, iters: usize) -> Fixture {
        let mut rng = ChaChaRng::from_seed(seed);
        let (x, y) = synth::gaussian_regression(&mut rng, 6, 2, 0.2);
        let q = QuantisedData::from_f64(&x, &y, 2);
        let (xq, _) = q.dequantised();
        let nu = nu_optimal(&xq);
        let params = plan(&PlanRequest::gd(6, 2, iters, 2, nu)).unwrap();
        let ctx = FvContext::new(params);
        let keys = keygen(&ctx, &mut rng);
        Fixture { ctx, keys, q, nu, rng }
    }

    #[test]
    fn tenant_queue_round_robin_is_fair() {
        let mut q: TenantQueues<u32> = TenantQueues::default();
        let (a, b) = (TenantId::new("a"), TenantId::new("b"));
        q.push(&a, 1);
        q.push(&a, 2);
        q.push(&a, 3);
        q.push(&b, 10);
        q.push(&b, 11);
        assert_eq!(q.pending(), 5);
        // Rotating cursor: a flooding tenant interleaves 1:1 with the
        // other tenant until one drains.
        let order: Vec<u32> = std::iter::from_fn(|| q.pop_fair()).collect();
        assert_eq!(order, vec![1, 10, 2, 11, 3]);
        assert_eq!(q.pending(), 0);
        assert!(q.pop_fair().is_none());
    }

    #[test]
    fn concurrent_jobs_complete_and_match_exact() {
        let mut f = fixture(601, 2);
        let native =
            Arc::new(NativeEngine::new(f.ctx.clone(), Arc::new(f.keys.rk.clone())));
        let engine = BatchingEngine::new(native, BatchConfig::default());
        let coord = Coordinator::new(engine.clone(), 4);

        let ids: Vec<JobId> = (0..3)
            .map(|_| {
                let data = encrypt_dataset(&f.ctx, &f.keys.pk, &f.q, &mut f.rng);
                coord
                    .submit(JobSpec::new(data, FitConfig::gd(2, f.nu), None))
                    .unwrap()
            })
            .collect();
        let expect = exact::gd_exact(&f.q, f.nu, 2).decode_last();
        for id in ids {
            coord.wait(id, Duration::from_secs(600)).unwrap();
            let fit = coord.take_result(id).unwrap();
            let dec = decrypt_coefficients(&f.ctx, &f.keys.sk, &fit);
            assert!(linf(&dec, &expect) < 1e-9);
        }
        assert_eq!(coord.metrics.jobs_completed.load(Ordering::Relaxed), 3);
        engine.shutdown();
    }

    #[test]
    fn oversized_job_is_rejected_at_submit() {
        let mut f = fixture(602, 1);
        let native =
            Arc::new(NativeEngine::new(f.ctx.clone(), Arc::new(f.keys.rk.clone())));
        let coord = Coordinator::new(native, 2);
        let data = encrypt_dataset(&f.ctx, &f.keys.pk, &f.q, &mut f.rng);
        // 10 iterations on 1-iteration params must be rejected.
        let err = coord
            .submit(JobSpec::new(data, FitConfig::gd(10, f.nu), None))
            .unwrap_err();
        assert_eq!(err.code, ErrorCode::AdmissionDenied);
        assert!(err.to_string().contains("rejected"), "{err}");
        assert_eq!(coord.metrics.jobs_rejected.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn zero_deadline_expires_before_any_engine_work() {
        let mut f = fixture(603, 2);
        let native =
            Arc::new(NativeEngine::new(f.ctx.clone(), Arc::new(f.keys.rk.clone())));
        let coord = Coordinator::new(native.clone(), 2);
        let data = encrypt_dataset(&f.ctx, &f.keys.pk, &f.q, &mut f.rng);
        let muls_before = native.stats().snapshot().0;
        // deadline_ms = 0: already past at pop time, deterministically.
        // (The submit-time estimator has no latency history yet, so the
        // job is admitted and must die at the queue boundary instead.)
        let id = coord
            .submit(JobSpec::new(data, FitConfig::gd(2, f.nu), None).with_deadline_ms(0))
            .unwrap();
        coord.wait(id, Duration::from_secs(600)).unwrap();
        assert_eq!(coord.state(id).as_deref(), Some("expired"));
        let err = coord.take_result(id).unwrap_err();
        assert_eq!(err.code, ErrorCode::DeadlineExceeded, "{err}");
        // The rejection happened *before* expensive work started: not
        // a single ciphertext multiplication ran.
        assert_eq!(native.stats().snapshot().0, muls_before);
        assert!(coord.metrics.jobs_expired.load(Ordering::Relaxed) >= 1);
        assert_eq!(coord.metrics.jobs_completed.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn infeasible_deadline_is_rejected_at_submit_once_calibrated() {
        let mut f = fixture(604, 2);
        let native =
            Arc::new(NativeEngine::new(f.ctx.clone(), Arc::new(f.keys.rk.clone())));
        let coord = Coordinator::new(native, 1);
        // Calibrate: one completed job gives the estimator a non-zero
        // mean service time.
        let data = encrypt_dataset(&f.ctx, &f.keys.pk, &f.q, &mut f.rng);
        let id = coord.submit(JobSpec::new(data, FitConfig::gd(2, f.nu), None)).unwrap();
        coord.wait(id, Duration::from_secs(600)).unwrap();
        let _ = coord.take_result(id).unwrap();
        assert!(coord.metrics.job_latency.mean_ms() > 0.0);
        // Now a 0ms deadline is provably infeasible at submit: the
        // client learns before shipping work into the queue.
        let data = encrypt_dataset(&f.ctx, &f.keys.pk, &f.q, &mut f.rng);
        let err = coord
            .submit(JobSpec::new(data, FitConfig::gd(2, f.nu), None).with_deadline_ms(0))
            .unwrap_err();
        assert_eq!(err.code, ErrorCode::DeadlineExceeded, "{err}");
        assert!(err.to_string().contains("infeasible"), "{err}");
    }

    #[test]
    fn queue_capacity_bounces_overloaded() {
        let mut f = fixture(605, 2);
        let native =
            Arc::new(NativeEngine::new(f.ctx.clone(), Arc::new(f.keys.rk.clone())));
        let coord = Coordinator::with_config(
            native,
            CoordinatorConfig { lanes: 1, queue_capacity: 2, ..CoordinatorConfig::default() },
        );
        // Saturate: with 1 lane and capacity 2, at least one of six
        // rapid submissions must bounce Overloaded (the lane cannot
        // drain 4 fits in the sub-millisecond submission burst —
        // datasets are pre-encrypted so the burst really is tight).
        let datasets: Vec<_> = (0..6)
            .map(|_| encrypt_dataset(&f.ctx, &f.keys.pk, &f.q, &mut f.rng))
            .collect();
        let mut accepted = Vec::new();
        let mut overloaded = 0;
        for data in datasets {
            match coord.submit(JobSpec::new(data, FitConfig::gd(2, f.nu), None)) {
                Ok(id) => accepted.push(id),
                Err(e) => {
                    assert_eq!(e.code, ErrorCode::Overloaded, "{e}");
                    overloaded += 1;
                }
            }
        }
        assert!(overloaded >= 1, "queue never reported overload");
        assert_eq!(
            coord.metrics.jobs_overloaded.load(Ordering::Relaxed),
            overloaded as u64
        );
        // Every accepted job still completes: bounded, not lossy.
        for id in accepted {
            coord.wait(id, Duration::from_secs(600)).unwrap();
            let _ = coord.take_result(id).unwrap();
        }
    }

    #[test]
    fn tenant_fairness_under_saturation() {
        let mut f = fixture(606, 2);
        let native =
            Arc::new(NativeEngine::new(f.ctx.clone(), Arc::new(f.keys.rk.clone())));
        let coord = Coordinator::new(native, 1);
        // Pre-encrypt so the submission burst is tight.
        let datasets: Vec<_> = (0..7)
            .map(|_| encrypt_dataset(&f.ctx, &f.keys.pk, &f.q, &mut f.rng))
            .collect();
        let mut it = datasets.into_iter();
        let flood = TenantId::new("flood");
        let small = TenantId::new("small");
        let flood_ids: Vec<JobId> = (0..6)
            .map(|_| {
                coord
                    .submit(
                        JobSpec::new(it.next().unwrap(), FitConfig::gd(2, f.nu), None)
                            .with_tenant(flood.clone()),
                    )
                    .unwrap()
            })
            .collect();
        let small_id = coord
            .submit(
                JobSpec::new(it.next().unwrap(), FitConfig::gd(2, f.nu), None)
                    .with_tenant(small.clone()),
            )
            .unwrap();
        for id in flood_ids.iter().chain([&small_id]) {
            coord.wait(*id, Duration::from_secs(600)).unwrap();
        }
        // Round-robin: the small tenant's single job must not wait out
        // the flooding tenant's whole backlog. It finishes strictly
        // before the flood's last job on the single lane.
        let small_done = coord.finished_at(small_id).unwrap();
        let flood_last = flood_ids.iter().map(|id| coord.finished_at(*id).unwrap()).max().unwrap();
        assert!(
            small_done < flood_last,
            "small tenant starved behind the flooding tenant's backlog"
        );
        assert_eq!(coord.metrics.jobs_completed.load(Ordering::Relaxed), 7);
        // Per-tenant counters saw the split.
        let ts = coord.tenants().get(&flood).unwrap();
        assert_eq!(ts.counters.jobs_completed.load(Ordering::Relaxed), 6);
        let ts = coord.tenants().get(&small).unwrap();
        assert_eq!(ts.counters.jobs_completed.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn wait_performs_constant_state_checks() {
        let mut f = fixture(607, 2);
        let native =
            Arc::new(NativeEngine::new(f.ctx.clone(), Arc::new(f.keys.rk.clone())));
        let coord = Coordinator::new(native, 1);
        // Single lane: the last job completes after all the others. A
        // waiter on it must sleep through the earlier completions —
        // per-job events, not a broadcast condvar.
        let ids: Vec<JobId> = (0..4)
            .map(|_| {
                let data = encrypt_dataset(&f.ctx, &f.keys.pk, &f.q, &mut f.rng);
                coord.submit(JobSpec::new(data, FitConfig::gd(2, f.nu), None)).unwrap()
            })
            .collect();
        let last = *ids.last().unwrap();
        let waiter = {
            let coord = Arc::clone(&coord);
            std::thread::spawn(move || coord.wait(last, Duration::from_secs(600)))
        };
        for id in &ids {
            coord.wait(*id, Duration::from_secs(600)).unwrap();
        }
        waiter.join().unwrap().unwrap();
        // The spawned waiter plus this thread's wait both parked on the
        // last job's event: entry + wakeup checks each, nothing per
        // unrelated completion. (A broadcast design would have paid a
        // check per finished job per waiter.)
        let checks = coord.event_checks(last).unwrap();
        assert!(checks <= 6, "long wait performed {checks} state checks");
        for id in ids {
            let _ = coord.take_result(id).unwrap();
        }
    }

    #[test]
    fn drain_bounces_queued_jobs_and_refuses_new_submissions() {
        let mut f = fixture(608, 2);
        let native =
            Arc::new(NativeEngine::new(f.ctx.clone(), Arc::new(f.keys.rk.clone())));
        let coord = Coordinator::new(native, 1);
        assert!(coord.is_accepting());
        // 4 jobs on one lane: the first starts, the rest sit queued
        // (fits are far slower than the pre-encrypted submit burst).
        let ids: Vec<JobId> = (0..4)
            .map(|_| {
                let data = encrypt_dataset(&f.ctx, &f.keys.pk, &f.q, &mut f.rng);
                coord.submit(JobSpec::new(data, FitConfig::gd(2, f.nu), None)).unwrap()
            })
            .collect();
        let report = coord.shutdown(Duration::from_secs(600));
        assert!(report.drained, "in-flight jobs must reach terminal states");
        assert!(report.bounced >= 1, "a 4-deep backlog on one lane must bounce something");
        assert!(!coord.is_accepting());
        assert_eq!(coord.queue_depth(), 0, "drain must leave no queued entries");
        // Deterministic resolution: every job is done or cancelled,
        // every waiter wakes immediately, cancelled jobs answer with
        // the structured shutting_down code.
        let mut done = 0u64;
        let mut cancelled = 0u64;
        for id in ids {
            coord.wait(id, Duration::from_secs(5)).unwrap();
            match coord.state(id).as_deref() {
                Some("done") => {
                    done += 1;
                    let _ = coord.take_result(id).unwrap();
                }
                Some("cancelled") => {
                    cancelled += 1;
                    let err = coord.take_result(id).unwrap_err();
                    assert_eq!(err.code, ErrorCode::ShuttingDown, "{err}");
                }
                s => panic!("job left in state {s:?} after drain"),
            }
        }
        assert!(done >= 1, "the running job must be allowed to finish");
        assert_eq!(cancelled, report.bounced);
        assert_eq!(
            coord.metrics.jobs_cancelled.load(Ordering::Relaxed),
            cancelled
        );
        // Admission is closed: a fresh submit bounces structurally.
        let data = encrypt_dataset(&f.ctx, &f.keys.pk, &f.q, &mut f.rng);
        let err =
            coord.submit(JobSpec::new(data, FitConfig::gd(2, f.nu), None)).unwrap_err();
        assert_eq!(err.code, ErrorCode::ShuttingDown, "{err}");
        // Second drain is an idempotent no-op.
        let again = coord.shutdown(Duration::from_secs(5));
        assert_eq!(again.bounced, 0);
        assert!(again.drained);
        assert_eq!(coord.tracked_jobs(), 0, "all results consumed, nothing leaked");
    }

    fn tmpdir(tag: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join(format!(
            "els-sched-{tag}-{}-{:?}",
            std::process::id(),
            std::thread::current().id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        dir
    }

    #[test]
    fn recovery_restores_unacked_results_with_zero_engine_work() {
        use crate::coordinator::protocol::fit_to_json;
        let mut f = fixture(610, 2);
        let dir = tmpdir("restore");
        let native_a =
            Arc::new(NativeEngine::new(f.ctx.clone(), Arc::new(f.keys.rk.clone())));
        // `recover` on an empty directory doubles as the journal-
        // enabled constructor.
        let coord_a =
            Coordinator::recover(native_a, CoordinatorConfig::default(), &dir).unwrap();
        assert_eq!(coord_a.recovered().total(), 0, "empty journal recovers nothing");
        let data = encrypt_dataset(&f.ctx, &f.keys.pk, &f.q, &mut f.rng);
        let id = coord_a
            .submit(JobSpec::new(data, FitConfig::gd(2, f.nu), None).with_token("durable-1"))
            .unwrap();
        coord_a.wait(id, Duration::from_secs(600)).unwrap();
        let fit_a = coord_a.peek_result(id).unwrap(); // delivered, never acked
        coord_a.crash();
        // Rebuild on a FRESH engine: re-serving the unacked result
        // must cost zero engine work, and the fresh engine's ct-mul
        // counter proves it.
        let native_b =
            Arc::new(NativeEngine::new(f.ctx.clone(), Arc::new(f.keys.rk.clone())));
        let coord_b =
            Coordinator::recover(native_b.clone(), CoordinatorConfig::default(), &dir).unwrap();
        assert_eq!(coord_b.recovered().restored, 1);
        assert_eq!(coord_b.recovered().requeued, 0);
        let fit_b = coord_b.peek_result(id).unwrap();
        assert_eq!(
            fit_to_json(&fit_b).to_string_json(),
            fit_to_json(&fit_a).to_string_json(),
            "re-served fit must be bit-identical to the original"
        );
        assert_eq!(native_b.stats().snapshot().0, 0, "re-serving must do zero engine work");
        // The idempotency token survived recovery: a client retry
        // re-attaches instead of paying for a second fit.
        let data2 = encrypt_dataset(&f.ctx, &f.keys.pk, &f.q, &mut f.rng);
        let id2 = coord_b
            .submit(JobSpec::new(data2, FitConfig::gd(2, f.nu), None).with_token("durable-1"))
            .unwrap();
        assert_eq!(id2, id, "recovered token table must dedup the retry");
        assert_eq!(native_b.stats().snapshot().0, 0);
        // Ack, drain, recover once more: the acked job stays gone.
        assert!(coord_b.release(id));
        coord_b.shutdown(Duration::from_secs(60));
        let native_c =
            Arc::new(NativeEngine::new(f.ctx.clone(), Arc::new(f.keys.rk.clone())));
        let coord_c =
            Coordinator::recover(native_c, CoordinatorConfig::default(), &dir).unwrap();
        assert_eq!(coord_c.recovered().total(), 0, "acked jobs must not be resurrected");
        assert_eq!(coord_c.peek_result(id).unwrap_err().code, ErrorCode::UnknownJob);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn recovery_resumes_mid_fit_from_journaled_checkpoint() {
        use crate::coordinator::protocol::fit_to_json;
        let mut f = fixture(611, 3);
        let dir = tmpdir("resume");
        let native_a =
            Arc::new(NativeEngine::new(f.ctx.clone(), Arc::new(f.keys.rk.clone())));
        let data = encrypt_dataset(&f.ctx, &f.keys.pk, &f.q, &mut f.rng);
        let cfg = FitConfig::gd(3, f.nu);
        // Reference: an uninterrupted fit, capturing the resume points
        // exactly as a journaling lane would have.
        let mut ckpts = Vec::new();
        let hook = CheckpointHook { every: 1, sink: Box::new(|c| ckpts.push(c)) };
        let reference = encrypted::fit_with_checkpoints(
            native_a.as_ref(),
            &DatasetRef::Scalar(&data),
            &cfg,
            None,
            Some(hook),
        )
        .unwrap()
        .fit;
        let full_muls = native_a.stats().snapshot().0;
        assert_eq!(ckpts.len(), 2, "3-iteration fit checkpoints at k=1 and k=2");
        // Forge the journal a crash mid-iteration-3 leaves behind:
        // accepted, started, checkpoints — and no `done`.
        let spec = JobSpec::new(data, cfg, None).with_token("resume-1");
        let (wal, _) = Journal::open(&dir).unwrap();
        wal.append_json(&journal::accepted_payload(JobId(7), &spec)).unwrap();
        wal.append(&JournalRecord::Started { id: JobId(7) }).unwrap();
        for ckpt in &ckpts {
            wal.append(&JournalRecord::Checkpoint { id: JobId(7), ckpt: ckpt.clone() }).unwrap();
        }
        drop(wal);
        let resumed_before = journal::checkpoints_resumed();
        let native_b =
            Arc::new(NativeEngine::new(f.ctx.clone(), Arc::new(f.keys.rk.clone())));
        let coord_b =
            Coordinator::recover(native_b.clone(), CoordinatorConfig::default(), &dir).unwrap();
        assert_eq!(coord_b.recovered().requeued, 1);
        assert_eq!(coord_b.recovered().resumed, 1);
        coord_b.wait(JobId(7), Duration::from_secs(600)).unwrap();
        let fit = coord_b.peek_result(JobId(7)).unwrap();
        assert_eq!(
            fit_to_json(&fit).to_string_json(),
            fit_to_json(&reference).to_string_json(),
            "resumed fit must be bit-identical to the uninterrupted run"
        );
        assert!(journal::checkpoints_resumed() > resumed_before);
        // Resuming from k=2 of 3 redoes only the tail, not the whole
        // fit: strictly fewer ct-muls than the full reference run.
        let resumed_muls = native_b.stats().snapshot().0;
        assert!(
            resumed_muls < full_muls,
            "resume redid the whole fit ({resumed_muls} vs {full_muls} ct-muls)"
        );
        // The id watermark survived: new work gets fresh ids.
        let data2 = encrypt_dataset(&f.ctx, &f.keys.pk, &f.q, &mut f.rng);
        let id2 = coord_b.submit(JobSpec::new(data2, FitConfig::gd(3, f.nu), None)).unwrap();
        assert!(id2.0 > 7, "recovered id watermark must advance past journaled ids");
        coord_b.wait(id2, Duration::from_secs(600)).unwrap();
        let _ = coord_b.take_result(id2).unwrap();
        assert!(coord_b.release(JobId(7)));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn idempotent_token_reattaches_without_second_execution() {
        let mut f = fixture(609, 2);
        let native =
            Arc::new(NativeEngine::new(f.ctx.clone(), Arc::new(f.keys.rk.clone())));
        let coord = Coordinator::new(native.clone(), 2);
        let data = encrypt_dataset(&f.ctx, &f.keys.pk, &f.q, &mut f.rng);
        let id = coord
            .submit(JobSpec::new(data, FitConfig::gd(2, f.nu), None).with_token("attempt-1"))
            .unwrap();
        coord.wait(id, Duration::from_secs(600)).unwrap();
        // The "retry": same (tenant, token), different payload bytes —
        // the server answers from the token table without running
        // anything (the ct-mul counter is the proof).
        let muls_before = native.stats().snapshot().0;
        let data2 = encrypt_dataset(&f.ctx, &f.keys.pk, &f.q, &mut f.rng);
        let id2 = coord
            .submit(JobSpec::new(data2, FitConfig::gd(2, f.nu), None).with_token("attempt-1"))
            .unwrap();
        assert_eq!(id2, id, "token retry must re-attach to the original job");
        assert_eq!(
            native.stats().snapshot().0,
            muls_before,
            "token dedup must not re-execute the fit"
        );
        assert_eq!(coord.metrics.jobs_deduped.load(Ordering::Relaxed), 1);
        // Peek is repeatable (at-least-once delivery)…
        let a = coord.peek_result(id).unwrap();
        let b = coord.peek_result(id).unwrap();
        assert_eq!(a.betas.len(), b.betas.len());
        // …and release is the explicit goodbye: job and token gone, so
        // the *same* token now names a fresh job.
        assert!(coord.release(id));
        assert!(!coord.release(id), "second ack is a no-op");
        assert_eq!(coord.peek_result(id).unwrap_err().code, ErrorCode::UnknownJob);
        let data3 = encrypt_dataset(&f.ctx, &f.keys.pk, &f.q, &mut f.rng);
        let id3 = coord
            .submit(JobSpec::new(data3, FitConfig::gd(2, f.nu), None).with_token("attempt-1"))
            .unwrap();
        assert_ne!(id3, id, "released token must not resurrect the old job");
        coord.wait(id3, Duration::from_secs(600)).unwrap();
        let _ = coord.take_result(id3).unwrap();
        // Different tenants never share a token namespace.
        let data4 = encrypt_dataset(&f.ctx, &f.keys.pk, &f.q, &mut f.rng);
        let id4 = coord
            .submit(
                JobSpec::new(data4, FitConfig::gd(2, f.nu), None)
                    .with_tenant(TenantId::new("other"))
                    .with_token("attempt-1"),
            )
            .unwrap();
        assert_ne!(id4, id3);
        coord.wait(id4, Duration::from_secs(600)).unwrap();
        let _ = coord.take_result(id4).unwrap();
    }
}
