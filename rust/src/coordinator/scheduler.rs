//! The coordinator: accepts encrypted regression jobs, runs admission
//! control, and executes them on worker threads over a shared (batching)
//! engine with bounded concurrency.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::util::error::{anyhow, Result};

use crate::els::encrypted::{self, EncryptedFit};
use crate::runtime::backend::HeEngine;
use crate::util::telemetry::{self, Phase};

use super::admission::{admit, AdmissionRequest};
use super::job::{Job, JobId, JobSpec, JobState};
use super::metrics::Metrics;

/// Counting semaphore (no tokio offline).
struct Semaphore {
    permits: Mutex<usize>,
    cv: Condvar,
}

impl Semaphore {
    fn new(n: usize) -> Self {
        Semaphore { permits: Mutex::new(n), cv: Condvar::new() }
    }

    fn acquire(&self) {
        let mut p = self.permits.lock().unwrap();
        while *p == 0 {
            p = self.cv.wait(p).unwrap();
        }
        *p -= 1;
    }

    fn release(&self) {
        *self.permits.lock().unwrap() += 1;
        self.cv.notify_one();
    }
}

/// The job coordinator.
pub struct Coordinator {
    engine: Arc<dyn HeEngine>,
    jobs: Mutex<BTreeMap<JobId, Job>>,
    done_cv: Condvar,
    next_id: AtomicU64,
    sem: Semaphore,
    pub metrics: Arc<Metrics>,
}

impl Coordinator {
    pub fn new(engine: Arc<dyn HeEngine>, max_concurrent: usize) -> Arc<Self> {
        Arc::new(Coordinator {
            engine,
            jobs: Mutex::new(BTreeMap::new()),
            done_cv: Condvar::new(),
            next_id: AtomicU64::new(1),
            sem: Semaphore::new(max_concurrent.max(1)),
            metrics: Arc::new(Metrics::default()),
        })
    }

    pub fn engine(&self) -> &Arc<dyn HeEngine> {
        &self.engine
    }

    /// Submit a job. Runs admission control synchronously; on success
    /// the fit executes on a worker thread.
    pub fn submit(self: &Arc<Self>, spec: JobSpec) -> Result<JobId> {
        self.metrics.jobs_submitted.fetch_add(1, Ordering::Relaxed);
        let req = AdmissionRequest {
            n_obs: spec.data.n(),
            p_vars: spec.data.p(),
            iters: spec.cfg.iters,
            phi: spec.data.phi,
            nu: spec.cfg.nu,
            accel: spec.cfg.accel,
            cd_updates: spec.cd_updates,
        };
        let admitted = {
            let _span = telemetry::span(Phase::JobAdmit);
            admit(&self.engine.ctx().params, &req)
        };
        if let Err(e) = admitted {
            self.metrics.jobs_rejected.fetch_add(1, Ordering::Relaxed);
            return Err(anyhow!(e));
        }
        let id = JobId(self.next_id.fetch_add(1, Ordering::Relaxed));
        self.jobs.lock().unwrap().insert(id, Job::new(id));
        let me = self.clone();
        std::thread::Builder::new()
            .name(format!("els-{id}"))
            .spawn(move || me.run_job(id, spec))
            .expect("spawning job worker");
        Ok(id)
    }

    fn run_job(self: &Arc<Self>, id: JobId, spec: JobSpec) {
        {
            // Time spent waiting on the concurrency semaphore = queueing.
            let _queued = telemetry::span(Phase::JobQueue);
            self.sem.acquire();
        }
        {
            let mut jobs = self.jobs.lock().unwrap();
            if let Some(j) = jobs.get_mut(&id) {
                j.state = JobState::Running;
            }
        }
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let _span = telemetry::span(Phase::JobExecute);
            match spec.cd_updates {
                Some(updates) => {
                    encrypted::fit_cd(self.engine.as_ref(), &spec.data, spec.cfg.nu, updates)
                }
                None => encrypted::fit(self.engine.as_ref(), &spec.data, &spec.cfg),
            }
        }));
        self.sem.release();
        let mut jobs = self.jobs.lock().unwrap();
        if let Some(j) = jobs.get_mut(&id) {
            j.finished = Some(Instant::now());
            match result {
                Ok(fit) => {
                    self.metrics.jobs_completed.fetch_add(1, Ordering::Relaxed);
                    if let Some(lat) = j.latency() {
                        self.metrics.job_latency.observe(lat);
                    }
                    j.state = JobState::Done(fit);
                }
                Err(e) => {
                    self.metrics.jobs_failed.fetch_add(1, Ordering::Relaxed);
                    let msg = e
                        .downcast_ref::<String>()
                        .cloned()
                        .or_else(|| e.downcast_ref::<&str>().map(|s| s.to_string()))
                        .unwrap_or_else(|| "job panicked".to_string());
                    j.state = JobState::Failed(msg);
                }
            }
        }
        self.done_cv.notify_all();
    }

    /// Current state label (None if unknown id).
    pub fn state(&self, id: JobId) -> Option<String> {
        self.jobs.lock().unwrap().get(&id).map(|j| j.state.label().to_string())
    }

    /// Block until the job leaves the queue/running states.
    pub fn wait(&self, id: JobId, timeout: Duration) -> Result<()> {
        let deadline = Instant::now() + timeout;
        let mut jobs = self.jobs.lock().unwrap();
        loop {
            match jobs.get(&id) {
                None => return Err(anyhow!("unknown job {id}")),
                Some(j) => match j.state {
                    JobState::Done(_) | JobState::Failed(_) => return Ok(()),
                    _ => {}
                },
            }
            let now = Instant::now();
            if now >= deadline {
                return Err(anyhow!("timeout waiting for {id}"));
            }
            let (guard, _) = self.done_cv.wait_timeout(jobs, deadline - now).unwrap();
            jobs = guard;
        }
    }

    /// Remove and return a finished fit.
    pub fn take_result(&self, id: JobId) -> Result<EncryptedFit> {
        let mut jobs = self.jobs.lock().unwrap();
        match jobs.get(&id).map(|j| j.state.label()) {
            None => Err(anyhow!("unknown job {id}")),
            Some("done") => {
                let job = jobs.remove(&id).unwrap();
                match job.state {
                    JobState::Done(fit) => Ok(fit),
                    _ => unreachable!(),
                }
            }
            Some("failed") => {
                let job = jobs.remove(&id).unwrap();
                match job.state {
                    JobState::Failed(msg) => Err(anyhow!("job failed: {msg}")),
                    _ => unreachable!(),
                }
            }
            Some(s) => Err(anyhow!("job {id} still {s}")),
        }
    }
}

#[cfg(test)]
mod tests {
    use std::sync::Arc;

    use super::*;
    use crate::data::synth;
    use crate::els::encrypted::{decrypt_coefficients, FitConfig};
    use crate::els::exact::{self, QuantisedData};
    use crate::els::float_ref::linf;
    use crate::els::model::encrypt_dataset;
    use crate::els::stepsize::nu_optimal;
    use crate::fhe::keys::keygen;
    use crate::fhe::params::{plan, PlanRequest};
    use crate::fhe::rng::ChaChaRng;
    use crate::fhe::FvContext;
    use crate::coordinator::batcher::{BatchConfig, BatchingEngine};
    use crate::runtime::backend::NativeEngine;

    #[test]
    fn concurrent_jobs_complete_and_match_exact() {
        let mut rng = ChaChaRng::from_seed(601);
        let (x, y) = synth::gaussian_regression(&mut rng, 6, 2, 0.2);
        let q = QuantisedData::from_f64(&x, &y, 2);
        let (xq, _) = q.dequantised();
        let nu = nu_optimal(&xq);
        let params = plan(&PlanRequest::gd(6, 2, 2, 2, nu)).unwrap();
        let ctx = FvContext::new(params);
        let keys = keygen(&ctx, &mut rng);
        let native = Arc::new(NativeEngine::new(ctx.clone(), Arc::new(keys.rk.clone())));
        let engine = BatchingEngine::new(native, BatchConfig::default());
        let coord = Coordinator::new(engine.clone(), 4);

        let ids: Vec<JobId> = (0..3)
            .map(|_| {
                let data = encrypt_dataset(&ctx, &keys.pk, &q, &mut rng);
                coord
                    .submit(JobSpec {
                        data,
                        cfg: FitConfig::gd(2, nu),
                        cd_updates: None,
                    })
                    .unwrap()
            })
            .collect();
        let expect = exact::gd_exact(&q, nu, 2).decode_last();
        for id in ids {
            coord.wait(id, Duration::from_secs(600)).unwrap();
            let fit = coord.take_result(id).unwrap();
            let dec = decrypt_coefficients(&ctx, &keys.sk, &fit);
            assert!(linf(&dec, &expect) < 1e-9);
        }
        assert_eq!(coord.metrics.jobs_completed.load(Ordering::Relaxed), 3);
        engine.shutdown();
    }

    #[test]
    fn oversized_job_is_rejected_at_submit() {
        let mut rng = ChaChaRng::from_seed(602);
        let (x, y) = synth::gaussian_regression(&mut rng, 6, 2, 0.2);
        let q = QuantisedData::from_f64(&x, &y, 2);
        let nu = 16;
        let params = plan(&PlanRequest::gd(6, 2, 1, 2, nu)).unwrap();
        let ctx = FvContext::new(params);
        let keys = keygen(&ctx, &mut rng);
        let native = Arc::new(NativeEngine::new(ctx.clone(), Arc::new(keys.rk.clone())));
        let coord = Coordinator::new(native, 2);
        let data = encrypt_dataset(&ctx, &keys.pk, &q, &mut rng);
        // 10 iterations on 1-iteration params must be rejected.
        let err = coord
            .submit(JobSpec { data, cfg: FitConfig::gd(10, nu), cd_updates: None })
            .unwrap_err();
        assert!(err.to_string().contains("rejected"), "{err}");
        assert_eq!(coord.metrics.jobs_rejected.load(Ordering::Relaxed), 1);
    }
}
