//! Wire protocol: line-delimited JSON with hex-packed polynomial
//! payloads (no serde offline; see `util::json`).
//!
//! Privacy model (paper §2): the client quantises, encodes and encrypts
//! locally; only ciphertexts, the public evaluation key and
//! data-independent config (N, P, K, ν, φ) cross the wire. The secret
//! key never leaves the client.

use crate::util::error::{anyhow, bail, Context, Result};

use crate::els::encrypted::{
    Accel, CheckpointState, DescentCheckpoint, EncryptedFit, FitConfig,
};
use crate::els::model::EncryptedDataset;
use crate::fhe::{Ciphertext, FvContext, RelinKey};
use crate::math::bigint::BigUint;
use crate::math::poly::{Rep, RnsPoly};
use crate::util::json::Json;

// ---- protocol version / structured errors -------------------------------

/// Wire schema version. Every request and reply carries `"v"`; the
/// server rejects mismatches with [`ErrorCode::BadVersion`] instead of
/// mis-parsing a future schema.
pub const PROTOCOL_VERSION: u64 = 1;

/// Record-codec version stamped (`"v"`) on ciphertext and fit
/// payloads alongside an FNV-1a record checksum (`"crc"`). Parsers
/// accept records without either field (pre-durability payloads) but
/// reject a present-but-wrong version or checksum with a structured
/// error — a journaled result must never decode to different polys
/// than were written.
pub const RECORD_VERSION: u64 = 1;

/// FNV-1a 64 over a byte stream — the record checksum used by the
/// ciphertext/fit codecs and the write-ahead journal framing (same
/// constants as `tenant::shard_of`; trivially mirrored in the Python
/// validators).
pub fn record_checksum(bytes: &[u8]) -> u64 {
    let mut h = Fnv::new();
    h.push(bytes);
    h.0
}

/// Streaming FNV-1a 64.
struct Fnv(u64);

impl Fnv {
    fn new() -> Fnv {
        Fnv(0xcbf2_9ce4_8422_2325)
    }

    fn push(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.0 ^= b as u64;
            self.0 = self.0.wrapping_mul(0x0100_0000_01b3);
        }
    }
}

/// Content checksum of a ciphertext record: depth, then each poly's
/// representation tag and raw little-endian limb words (exactly the
/// bytes the `hex` field spells).
fn ct_crc(ct: &Ciphertext) -> u64 {
    let mut h = Fnv::new();
    h.push(&(ct.ct_depth as u64).to_le_bytes());
    for p in &ct.polys {
        h.push(&[if p.rep == Rep::Ntt { b'n' } else { b'c' }]);
        for w in p.planes.iter().flatten() {
            h.push(&w.to_le_bytes());
        }
    }
    h.0
}

/// Content checksum of a fit record: decode metadata plus every
/// coefficient ciphertext's [`ct_crc`] — a dropped or reordered beta
/// changes the checksum even though each remaining ct is intact.
fn fit_crc(fit: &EncryptedFit) -> u64 {
    let mut h = Fnv::new();
    h.push(&(fit.phi as u64).to_le_bytes());
    h.push(&(fit.paper_mmd as u64).to_le_bytes());
    h.push(&(fit.noise_depth as u64).to_le_bytes());
    h.push(fit.divisor.to_decimal().as_bytes());
    for b in &fit.betas {
        h.push(&ct_crc(b).to_le_bytes());
    }
    h.0
}

/// Checksums serialise as 16 hex chars (LE bytes, same convention as
/// poly payloads) — `util::json` numbers are f64 and cannot hold u64.
fn crc_to_json(crc: u64) -> Json {
    Json::Str(to_hex(std::iter::once(crc)))
}

/// The optional `"crc"` field of a record (`None` = legacy payload).
fn crc_from_json(j: &Json, what: &str) -> Result<Option<u64>> {
    match j.get("crc") {
        None => Ok(None),
        Some(c) => {
            let words = from_hex(c.as_str().context("crc")?)?;
            if words.len() != 1 {
                bail!("{what} crc must be exactly 8 bytes");
            }
            Ok(Some(words[0]))
        }
    }
}

/// Reject a present-but-unknown record version; absent = legacy.
fn version_guard(j: &Json, what: &str) -> Result<()> {
    if let Some(v) = j.get("v") {
        if v.as_u64() != Some(RECORD_VERSION) {
            bail!("{what} record version mismatch (supported: {RECORD_VERSION})");
        }
    }
    Ok(())
}

/// Structured error codes carried on the wire (`"code"` on error
/// replies) and surfaced through `Client`, so callers match on a code
/// instead of grepping message strings.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ErrorCode {
    /// Request `"v"` missing or not [`PROTOCOL_VERSION`].
    BadVersion,
    /// Malformed request (unparseable JSON, missing fields, bad codec).
    BadRequest,
    /// §4.5 admission rejection: parameters cannot support the job.
    AdmissionDenied,
    /// Pending queue at capacity; resubmit later.
    Overloaded,
    /// Deadline already infeasible at submit, or expired before the
    /// job reached an execution lane.
    DeadlineExceeded,
    /// No such job id.
    UnknownJob,
    /// The job ran and failed (panic or engine error).
    JobFailed,
    /// Server-side invariant violation.
    Internal,
    /// Client-side transport failure (connect/read/write/parse).
    Transport,
    /// Server is draining: admission stopped, queued jobs bounced.
    ShuttingDown,
}

impl ErrorCode {
    /// Whether a client retry can possibly succeed. Only transient
    /// conditions qualify: a transport hiccup or a momentarily full
    /// queue. Everything else is deterministic — retrying a
    /// `bad_request` or an `admission_denied` reproduces the failure
    /// and burns an encrypted-fit slot doing it.
    pub fn retryable(self) -> bool {
        matches!(self, ErrorCode::Transport | ErrorCode::Overloaded)
    }

    pub fn as_str(self) -> &'static str {
        match self {
            ErrorCode::BadVersion => "bad_version",
            ErrorCode::BadRequest => "bad_request",
            ErrorCode::AdmissionDenied => "admission_denied",
            ErrorCode::Overloaded => "overloaded",
            ErrorCode::DeadlineExceeded => "deadline_exceeded",
            ErrorCode::UnknownJob => "unknown_job",
            ErrorCode::JobFailed => "job_failed",
            ErrorCode::Internal => "internal",
            ErrorCode::Transport => "transport",
            ErrorCode::ShuttingDown => "shutting_down",
        }
    }

    pub fn from_str(s: &str) -> Option<ErrorCode> {
        Some(match s {
            "bad_version" => ErrorCode::BadVersion,
            "bad_request" => ErrorCode::BadRequest,
            "admission_denied" => ErrorCode::AdmissionDenied,
            "overloaded" => ErrorCode::Overloaded,
            "deadline_exceeded" => ErrorCode::DeadlineExceeded,
            "unknown_job" => ErrorCode::UnknownJob,
            "job_failed" => ErrorCode::JobFailed,
            "internal" => ErrorCode::Internal,
            "transport" => ErrorCode::Transport,
            "shutting_down" => ErrorCode::ShuttingDown,
            _ => return None,
        })
    }
}

impl std::fmt::Display for ErrorCode {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.as_str())
    }
}

/// A coded wire error. Implements `std::error::Error`, so it converts
/// into `util::error::Error` via the blanket `From` when a caller only
/// wants the flattened message.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct WireError {
    pub code: ErrorCode,
    pub message: String,
}

impl WireError {
    pub fn new(code: ErrorCode, message: impl Into<String>) -> Self {
        WireError { code, message: message.into() }
    }

    pub fn bad_request(message: impl Into<String>) -> Self {
        WireError::new(ErrorCode::BadRequest, message)
    }

    pub fn internal(message: impl Into<String>) -> Self {
        WireError::new(ErrorCode::Internal, message)
    }

    pub fn transport(message: impl Into<String>) -> Self {
        WireError::new(ErrorCode::Transport, message)
    }
}

impl std::fmt::Display for WireError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "[{}] {}", self.code, self.message)
    }
}

impl std::error::Error for WireError {}

pub type WireResult<T> = std::result::Result<T, WireError>;

// ---- hex helpers -------------------------------------------------------

const HEX: &[u8; 16] = b"0123456789abcdef";

fn to_hex(words: impl Iterator<Item = u64>) -> String {
    let mut s = String::new();
    for w in words {
        for b in w.to_le_bytes() {
            s.push(HEX[(b >> 4) as usize] as char);
            s.push(HEX[(b & 15) as usize] as char);
        }
    }
    s
}

fn from_hex(s: &str) -> Result<Vec<u64>> {
    let b = s.as_bytes();
    if b.len() % 16 != 0 {
        bail!("hex payload length {} not a multiple of 16", b.len());
    }
    fn nib(c: u8) -> Result<u64> {
        match c {
            b'0'..=b'9' => Ok((c - b'0') as u64),
            b'a'..=b'f' => Ok((c - b'a' + 10) as u64),
            b'A'..=b'F' => Ok((c - b'A' + 10) as u64),
            _ => bail!("invalid hex digit"),
        }
    }
    let mut out = Vec::with_capacity(b.len() / 16);
    for chunk in b.chunks(16) {
        let mut w = 0u64;
        for (i, pair) in chunk.chunks(2).enumerate() {
            let byte = (nib(pair[0])? << 4) | nib(pair[1])?;
            w |= byte << (8 * i);
        }
        out.push(w);
    }
    Ok(out)
}

// ---- polynomial / ciphertext codecs ------------------------------------

pub fn poly_to_json(p: &RnsPoly) -> Json {
    Json::obj(vec![
        ("rep", Json::str(if p.rep == Rep::Ntt { "ntt" } else { "coeff" })),
        ("hex", Json::Str(to_hex(p.planes.iter().flatten().copied()))),
    ])
}

pub fn poly_from_json(ctx: &FvContext, j: &Json) -> Result<RnsPoly> {
    let ring = &ctx.ring_q;
    let words = from_hex(j.req("hex")?.as_str().context("hex")?)?;
    let (l, d) = (ring.nlimbs(), ring.d);
    if words.len() != l * d {
        bail!("polynomial payload has {} words, expected {}", words.len(), l * d);
    }
    let rep = match j.req("rep")?.as_str() {
        Some("ntt") => Rep::Ntt,
        _ => Rep::Coeff,
    };
    let planes = (0..l).map(|i| words[i * d..(i + 1) * d].to_vec()).collect();
    // Validate residues are canonical.
    let poly = RnsPoly { d, planes, rep };
    for (plane, &pr) in poly.planes.iter().zip(&ring.basis.primes) {
        if plane.iter().any(|&v| v >= pr) {
            bail!("non-canonical residue in payload");
        }
    }
    Ok(poly)
}

pub fn ct_to_json(ct: &Ciphertext) -> Json {
    Json::obj(vec![
        ("v", Json::Num(RECORD_VERSION as f64)),
        ("depth", Json::Num(ct.ct_depth as f64)),
        ("polys", Json::Arr(ct.polys.iter().map(poly_to_json).collect())),
        ("crc", crc_to_json(ct_crc(ct))),
    ])
}

pub fn ct_from_json(ctx: &FvContext, j: &Json) -> Result<Ciphertext> {
    version_guard(j, "ciphertext")?;
    let polys: Result<Vec<RnsPoly>> = j
        .req("polys")?
        .as_arr()
        .context("polys")?
        .iter()
        .map(|p| poly_from_json(ctx, p))
        .collect();
    let polys = polys?;
    if polys.len() < 2 || polys.len() > 3 {
        bail!("ciphertext must have 2 or 3 polynomials");
    }
    let mut ct = Ciphertext::new(polys);
    ct.ct_depth = j.get("depth").and_then(|d| d.as_u64()).unwrap_or(0) as u32;
    if let Some(want) = crc_from_json(j, "ciphertext")? {
        let got = ct_crc(&ct);
        if got != want {
            bail!("ciphertext record checksum mismatch (corrupted or tampered payload)");
        }
    }
    Ok(ct)
}

pub fn dataset_to_json(data: &EncryptedDataset) -> Json {
    Json::obj(vec![
        ("phi", Json::Num(data.phi as f64)),
        (
            "x",
            Json::Arr(
                data.x
                    .iter()
                    .map(|row| Json::Arr(row.iter().map(ct_to_json).collect()))
                    .collect(),
            ),
        ),
        ("y", Json::Arr(data.y.iter().map(ct_to_json).collect())),
    ])
}

pub fn dataset_from_json(ctx: &FvContext, j: &Json) -> Result<EncryptedDataset> {
    let x: Result<Vec<Vec<Ciphertext>>> = j
        .req("x")?
        .as_arr()
        .context("x")?
        .iter()
        .map(|row| {
            row.as_arr()
                .context("x row")?
                .iter()
                .map(|c| ct_from_json(ctx, c))
                .collect()
        })
        .collect();
    let y: Result<Vec<Ciphertext>> = j
        .req("y")?
        .as_arr()
        .context("y")?
        .iter()
        .map(|c| ct_from_json(ctx, c))
        .collect();
    let phi = j.req("phi")?.as_u64().context("phi")? as u32;
    let data = EncryptedDataset { x: x?, y: y?, phi };
    if data.x.is_empty() || data.x.iter().any(|r| r.len() != data.p()) {
        bail!("ragged design matrix");
    }
    if data.y.len() != data.n() {
        bail!("response length mismatch");
    }
    Ok(data)
}

pub fn relin_key_to_json(rk: &RelinKey) -> Json {
    Json::obj(vec![
        ("b", Json::Arr(rk.b_ntt.iter().map(poly_to_json).collect())),
        ("a", Json::Arr(rk.a_ntt.iter().map(poly_to_json).collect())),
    ])
}

pub fn relin_key_from_json(ctx: &FvContext, j: &Json) -> Result<RelinKey> {
    let parse = |key: &str| -> Result<Vec<RnsPoly>> {
        j.req(key)?
            .as_arr()
            .context("relin key array")?
            .iter()
            .map(|p| poly_from_json(ctx, p))
            .collect()
    };
    let (b, a) = (parse("b")?, parse("a")?);
    if b.len() != a.len() || b.len() != ctx.relin_ndigits {
        bail!("relin key digit count mismatch (got {}, need {})", b.len(), ctx.relin_ndigits);
    }
    Ok(RelinKey { b_ntt: b, a_ntt: a })
}

/// Galois rotation keys: same per-limb gadget shape as the relin key,
/// one entry per Galois element. Scalar key sets serialise as `[]`.
pub fn galois_keys_to_json(gk: &crate::fhe::GaloisKeys) -> Json {
    Json::Arr(
        gk.iter()
            .map(|k| {
                Json::obj(vec![
                    ("galois", Json::Num(k.galois as f64)),
                    ("b", Json::Arr(k.b_ntt.iter().map(poly_to_json).collect())),
                    ("a", Json::Arr(k.a_ntt.iter().map(poly_to_json).collect())),
                ])
            })
            .collect(),
    )
}

pub fn galois_keys_from_json(ctx: &FvContext, j: &Json) -> Result<crate::fhe::GaloisKeys> {
    let keys: Result<Vec<crate::fhe::GaloisKey>> = j
        .as_arr()
        .context("galois key array")?
        .iter()
        .map(|entry| {
            let galois = entry.req("galois")?.as_usize().context("galois element")?;
            if galois % 2 == 0 || galois >= 2 * ctx.d() {
                bail!("galois element {galois} is not an odd unit mod 2d");
            }
            let parse = |key: &str| -> Result<Vec<RnsPoly>> {
                entry
                    .req(key)?
                    .as_arr()
                    .context("galois key digit array")?
                    .iter()
                    .map(|p| poly_from_json(ctx, p))
                    .collect()
            };
            let (b, a) = (parse("b")?, parse("a")?);
            if b.len() != a.len() || b.len() != ctx.relin_ndigits {
                bail!(
                    "galois key digit count mismatch (got {}, need {})",
                    b.len(),
                    ctx.relin_ndigits
                );
            }
            Ok(crate::fhe::GaloisKey { galois, b_ntt: b, a_ntt: a })
        })
        .collect();
    Ok(crate::fhe::GaloisKeys::from_keys(keys?))
}

// ---- fit config / results ----------------------------------------------

pub fn accel_to_str(a: Accel) -> &'static str {
    match a {
        Accel::None => "gd",
        Accel::Vwt => "vwt",
        Accel::Nag => "nag",
    }
}

pub fn accel_from_str(s: &str) -> Result<Accel> {
    match s {
        "gd" | "none" => Ok(Accel::None),
        "vwt" => Ok(Accel::Vwt),
        "nag" => Ok(Accel::Nag),
        _ => Err(anyhow!("unknown acceleration '{s}' (gd|vwt|nag)")),
    }
}

pub fn cfg_to_json(cfg: &FitConfig, cd_updates: Option<usize>) -> Json {
    let mut fields = vec![
        ("iters", Json::Num(cfg.iters as f64)),
        ("nu", Json::Num(cfg.nu as f64)),
        ("accel", Json::str(accel_to_str(cfg.accel))),
    ];
    if let Some(u) = cd_updates {
        fields.push(("cd_updates", Json::Num(u as f64)));
    }
    Json::obj(fields)
}

pub fn cfg_from_json(j: &Json) -> Result<(FitConfig, Option<usize>)> {
    let iters = j.req("iters")?.as_usize().context("iters")?;
    let nu = j.req("nu")?.as_u64().context("nu")?;
    let accel = accel_from_str(j.req("accel")?.as_str().context("accel")?)?;
    let cd = j.get("cd_updates").and_then(|v| v.as_usize());
    Ok((FitConfig { iters, nu, accel, keep_path: false }, cd))
}

pub fn fit_to_json(fit: &EncryptedFit) -> Json {
    Json::obj(vec![
        ("v", Json::Num(RECORD_VERSION as f64)),
        ("betas", Json::Arr(fit.betas.iter().map(ct_to_json).collect())),
        ("divisor", Json::str(&fit.divisor.to_decimal())),
        ("phi", Json::Num(fit.phi as f64)),
        ("paper_mmd", Json::Num(fit.paper_mmd as f64)),
        ("noise_depth", Json::Num(fit.noise_depth as f64)),
        ("crc", crc_to_json(fit_crc(fit))),
    ])
}

pub fn fit_from_json(ctx: &FvContext, j: &Json) -> Result<EncryptedFit> {
    version_guard(j, "fit")?;
    let betas: Result<Vec<Ciphertext>> = j
        .req("betas")?
        .as_arr()
        .context("betas")?
        .iter()
        .map(|c| ct_from_json(ctx, c))
        .collect();
    let fit = EncryptedFit {
        betas: betas?,
        divisor: BigUint::from_decimal(j.req("divisor")?.as_str().context("divisor")?)
            .ok_or_else(|| anyhow!("bad divisor"))?,
        path: None,
        phi: j.req("phi")?.as_u64().context("phi")? as u32,
        paper_mmd: j.req("paper_mmd")?.as_u64().unwrap_or(0) as u32,
        noise_depth: j.req("noise_depth")?.as_u64().unwrap_or(0) as u32,
    };
    if let Some(want) = crc_from_json(j, "fit")? {
        let got = fit_crc(&fit);
        if got != want {
            bail!("fit record checksum mismatch (truncated or tampered record)");
        }
    }
    Ok(fit)
}

// ---- descent checkpoint codec ------------------------------------------

/// Serialise a mid-fit resume point. Ciphertexts go through
/// [`ct_to_json`] (representation-tagged, checksummed), so a journaled
/// checkpoint decodes to bit-identical polys and a resumed fit matches
/// an uninterrupted one exactly. CD's untouched coordinates serialise
/// as `null`.
pub fn checkpoint_to_json(c: &DescentCheckpoint) -> Json {
    let cts = |v: &[Ciphertext]| Json::Arr(v.iter().map(ct_to_json).collect());
    let paths =
        |p: &[Vec<Ciphertext>]| Json::Arr(p.iter().map(|row| cts(row)).collect());
    let mut fields = vec![
        ("v", Json::Num(RECORD_VERSION as f64)),
        ("phi", Json::Num(c.phi as f64)),
        ("nu", Json::Num(c.nu as f64)),
        ("done", Json::Num(c.done as f64)),
    ];
    match &c.state {
        CheckpointState::Gd { beta, path } => {
            fields.push(("algo", Json::str("gd")));
            fields.push(("beta", cts(beta)));
            fields.push(("path", paths(path)));
        }
        CheckpointState::Nag { beta, s_prev, path } => {
            fields.push(("algo", Json::str("nag")));
            fields.push(("beta", cts(beta)));
            fields.push(("s_prev", cts(s_prev)));
            fields.push(("path", paths(path)));
        }
        CheckpointState::Cd { beta, r } => {
            fields.push(("algo", Json::str("cd")));
            fields.push((
                "beta",
                Json::Arr(
                    beta.iter()
                        .map(|b| b.as_ref().map(ct_to_json).unwrap_or(Json::Null))
                        .collect(),
                ),
            ));
            fields.push(("r", cts(r)));
        }
    }
    Json::obj(fields)
}

pub fn checkpoint_from_json(ctx: &FvContext, j: &Json) -> Result<DescentCheckpoint> {
    version_guard(j, "checkpoint")?;
    let cts = |key: &str| -> Result<Vec<Ciphertext>> {
        j.req(key)?
            .as_arr()
            .with_context(|| format!("checkpoint {key}"))?
            .iter()
            .map(|c| ct_from_json(ctx, c))
            .collect()
    };
    let paths = || -> Result<Vec<Vec<Ciphertext>>> {
        j.req("path")?
            .as_arr()
            .context("checkpoint path")?
            .iter()
            .map(|row| {
                row.as_arr()
                    .context("checkpoint path row")?
                    .iter()
                    .map(|c| ct_from_json(ctx, c))
                    .collect()
            })
            .collect()
    };
    let state = match j.req("algo")?.as_str().context("checkpoint algo")? {
        "gd" => CheckpointState::Gd { beta: cts("beta")?, path: paths()? },
        "nag" => CheckpointState::Nag {
            beta: cts("beta")?,
            s_prev: cts("s_prev")?,
            path: paths()?,
        },
        "cd" => CheckpointState::Cd {
            beta: j
                .req("beta")?
                .as_arr()
                .context("checkpoint beta")?
                .iter()
                .map(|b| match b {
                    Json::Null => Ok(None),
                    other => ct_from_json(ctx, other).map(Some),
                })
                .collect::<Result<_>>()?,
            r: cts("r")?,
        },
        other => bail!("unknown checkpoint algorithm '{other}'"),
    };
    Ok(DescentCheckpoint {
        phi: j.req("phi")?.as_u64().context("checkpoint phi")? as u32,
        nu: j.req("nu")?.as_u64().context("checkpoint nu")?,
        done: j.req("done")?.as_usize().context("checkpoint done")?,
        state,
    })
}


// ---- parameter-set / key-file codecs ------------------------------------

pub fn params_to_json(p: &crate::fhe::FvParams) -> Json {
    Json::obj(vec![
        ("d", Json::Num(p.d as f64)),
        ("q_count", Json::Num(p.q_count as f64)),
        ("ext_count", Json::Num(p.ext_count as f64)),
        ("t_hex", Json::Str(to_hex(p.t.limbs().iter().copied()))),
        ("cbd_k", Json::Num(p.cbd_k as f64)),
        (
            "mul_backend",
            Json::str(match p.mul_backend {
                crate::fhe::MulBackend::ExactBigint => "bigint",
                crate::fhe::MulBackend::FullRns => "rns",
            }),
        ),
        (
            "profile",
            Json::str(match p.profile {
                crate::fhe::SecurityProfile::Toy => "toy",
                crate::fhe::SecurityProfile::Paper128 => "paper128",
            }),
        ),
        (
            "encoding",
            Json::str(match p.encoding {
                crate::fhe::Encoding::Scalar => "scalar",
                crate::fhe::Encoding::Packed => "packed",
            }),
        ),
    ])
}

pub fn params_from_json(j: &Json) -> Result<crate::fhe::FvParams> {
    let t = BigUint::from_limbs(from_hex(j.req("t_hex")?.as_str().context("t_hex")?)?);
    let params = crate::fhe::FvParams {
        d: j.req("d")?.as_usize().context("d")?,
        q_count: j.req("q_count")?.as_usize().context("q_count")?,
        ext_count: j.req("ext_count")?.as_usize().context("ext_count")?,
        t,
        cbd_k: j.req("cbd_k")?.as_u64().context("cbd_k")? as u32,
        // Absent ⇒ the process default (the key file predates the
        // backend field or defers the choice to the server); anything
        // else must fail loudly, not silently fall back.
        mul_backend: match j.get("mul_backend").and_then(|v| v.as_str()) {
            Some("bigint") => crate::fhe::MulBackend::ExactBigint,
            Some("rns") => crate::fhe::MulBackend::FullRns,
            None => crate::fhe::MulBackend::from_env(),
            Some(other) => bail!("unknown mul_backend '{other}' (rns|bigint)"),
        },
        profile: match j.req("profile")?.as_str() {
            Some("paper128") => crate::fhe::SecurityProfile::Paper128,
            _ => crate::fhe::SecurityProfile::Toy,
        },
        // Absent ⇒ scalar: key files predate slot packing. A packed
        // tag is re-validated below (t ≡ 1 mod 2d), so a tampered or
        // mismatched wire params set fails here, not deep in keygen.
        encoding: match j.get("encoding").and_then(|v| v.as_str()) {
            Some("packed") => crate::fhe::Encoding::Packed,
            Some("scalar") | None => crate::fhe::Encoding::Scalar,
            Some(other) => bail!("unknown encoding '{other}' (scalar|packed)"),
        },
    };
    params.validate_encoding()?;
    Ok(params)
}

/// Full key-file codec (params + sk + pk + rk). The secret key is
/// included — this file must stay on the data-holder side; the server
/// needs only `public_json` (params + pk + rk).
pub fn keyset_to_json(params: &crate::fhe::FvParams, keys: &crate::fhe::KeySet) -> Json {
    Json::obj(vec![
        ("params", params_to_json(params)),
        ("sk", poly_to_json(&keys.sk.s)),
        (
            "pk",
            Json::obj(vec![
                ("b", poly_to_json(&keys.pk.b_ntt)),
                ("a", poly_to_json(&keys.pk.a_ntt)),
            ]),
        ),
        ("rk", relin_key_to_json(&keys.rk)),
        ("gk", galois_keys_to_json(&keys.gk)),
    ])
}

pub fn keyset_from_json(j: &Json) -> Result<(std::sync::Arc<FvContext>, crate::fhe::KeySet)> {
    let params = params_from_json(j.req("params")?)?;
    let ctx = FvContext::new(params);
    let s = poly_from_json(&ctx, j.req("sk")?)?;
    let ring = &ctx.ring_q;
    let mut s_ntt = s.clone();
    ring.ntt_forward(&mut s_ntt);
    let s2_ntt = ring.mul_ntt(&s_ntt, &s_ntt);
    let pk = j.req("pk")?;
    let keys = crate::fhe::KeySet {
        sk: crate::fhe::SecretKey { s, s_ntt, s2_ntt },
        pk: crate::fhe::PublicKey {
            b_ntt: poly_from_json(&ctx, pk.req("b")?)?,
            a_ntt: poly_from_json(&ctx, pk.req("a")?)?,
        },
        rk: relin_key_from_json(&ctx, j.req("rk")?)?,
        // Absent ⇒ empty: scalar key files (and any predating slot
        // packing) carry no rotation keys.
        gk: match j.get("gk") {
            Some(gk) => galois_keys_from_json(&ctx, gk)?,
            None => crate::fhe::GaloisKeys::default(),
        },
    };
    Ok((ctx, keys))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fhe::encoding::encode_int;
    use crate::fhe::keys::keygen;
    use crate::fhe::params::FvParams;
    use crate::fhe::rng::ChaChaRng;

    #[test]
    fn hex_roundtrip() {
        let words = vec![0u64, 1, u64::MAX, 0xdead_beef];
        let hex = to_hex(words.iter().copied());
        assert_eq!(from_hex(&hex).unwrap(), words);
        assert!(from_hex("abc").is_err());
        assert!(from_hex("zz00000000000000").is_err());
    }

    #[test]
    fn ciphertext_roundtrip() {
        let ctx = FvContext::new(FvParams::custom(256, 3, 20));
        let mut rng = ChaChaRng::from_seed(701);
        let keys = keygen(&ctx, &mut rng);
        let mut ct = ctx.encrypt(&encode_int(-12345, ctx.d()), &keys.pk, &mut rng);
        ct.ct_depth = 3;
        let j = ct_to_json(&ct);
        let text = j.to_string_json();
        let back = ct_from_json(&ctx, &Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back.polys, ct.polys);
        assert_eq!(back.ct_depth, 3);
        let pt = ctx.decrypt(&back, &keys.sk);
        assert_eq!(pt.eval_at_2().to_i128(), Some(-12345));
    }

    #[test]
    fn rejects_tampered_residues() {
        let ctx = FvContext::new(FvParams::custom(256, 2, 16));
        let mut rng = ChaChaRng::from_seed(702);
        let keys = keygen(&ctx, &mut rng);
        let ct = ctx.encrypt(&encode_int(1, ctx.d()), &keys.pk, &mut rng);
        let j = ct_to_json(&ct).to_string_json();
        // Corrupt: set a residue ≥ prime by flipping high hex digits.
        let bad = j.replacen("\"hex\":\"", "\"hex\":\"ffffffffffffffff", 1);
        let parsed = Json::parse(&bad).unwrap();
        assert!(ct_from_json(&ctx, &parsed).is_err());
    }

    #[test]
    fn relin_key_roundtrip() {
        let ctx = FvContext::new(FvParams::custom(256, 2, 16));
        let mut rng = ChaChaRng::from_seed(703);
        let keys = keygen(&ctx, &mut rng);
        let j = relin_key_to_json(&keys.rk).to_string_json();
        let back = relin_key_from_json(&ctx, &Json::parse(&j).unwrap()).unwrap();
        assert_eq!(back.b_ntt, keys.rk.b_ntt);
        assert_eq!(back.a_ntt, keys.rk.a_ntt);
    }

    #[test]
    fn dataset_roundtrip_and_validation() {
        let ctx = FvContext::new(FvParams::custom(256, 2, 16));
        let mut rng = ChaChaRng::from_seed(705);
        let keys = keygen(&ctx, &mut rng);
        let q = crate::els::exact::QuantisedData {
            x: vec![vec![12, -3], vec![7, 99]],
            y: vec![-5, 41],
            phi: 2,
        };
        let data = crate::els::model::encrypt_dataset(&ctx, &keys.pk, &q, &mut rng);
        let j = dataset_to_json(&data).to_string_json();
        let back = dataset_from_json(&ctx, &Json::parse(&j).unwrap()).unwrap();
        assert_eq!(back.n(), 2);
        assert_eq!(back.p(), 2);
        let pt = ctx.decrypt(&back.x[1][1], &keys.sk);
        assert_eq!(pt.eval_at_2().to_i128(), Some(99));
        // Ragged matrices are rejected.
        let mut bad = Json::parse(&j).unwrap();
        if let Json::Obj(m) = &mut bad {
            if let Some(Json::Arr(rows)) = m.get_mut("x") {
                if let Json::Arr(r0) = &mut rows[0] {
                    r0.pop();
                }
            }
        }
        assert!(dataset_from_json(&ctx, &bad).is_err());
    }

    #[test]
    fn keyset_roundtrip() {
        let params = FvParams::custom(256, 2, 16);
        let ctx = FvContext::new(params.clone());
        let mut rng = ChaChaRng::from_seed(704);
        let keys = keygen(&ctx, &mut rng);
        let j = keyset_to_json(&params, &keys).to_string_json();
        let (ctx2, keys2) = keyset_from_json(&Json::parse(&j).unwrap()).unwrap();
        assert_eq!(ctx2.d(), ctx.d());
        // Encrypt under original pk, decrypt with restored sk.
        let ct = ctx.encrypt(&encode_int(77, ctx.d()), &keys.pk, &mut rng);
        let pt = ctx2.decrypt(&ct, &keys2.sk);
        assert_eq!(pt.eval_at_2().to_i128(), Some(77));
    }

    #[test]
    fn packed_keyset_roundtrip_carries_galois_keys() {
        use crate::fhe::encoding::Encoder;
        let params = FvParams::custom_packed(256, 2, 16).unwrap();
        let ctx = FvContext::new(params.clone());
        let mut rng = ChaChaRng::from_seed(706);
        let keys = keygen(&ctx, &mut rng);
        assert!(!keys.gk.is_empty());
        let j = keyset_to_json(&params, &keys).to_string_json();
        let (ctx2, keys2) = keyset_from_json(&Json::parse(&j).unwrap()).unwrap();
        assert_eq!(ctx2.params.encoding, crate::fhe::Encoding::Packed);
        let original: Vec<usize> = keys.gk.elements().collect();
        let restored: Vec<usize> = keys2.gk.elements().collect();
        assert_eq!(restored, original);
        // The restored keys must actually rotate: encrypt a packed
        // vector, rotate one step under the roundtripped key set,
        // decrypt with the roundtripped secret key.
        let vals: Vec<i64> = (0..8).collect();
        let ct = ctx.encrypt(&ctx.encoder().encode_vec(&vals), &keys.pk, &mut rng);
        let rot = ctx2.rotate_rows(&ct, 1, &keys2.gk);
        let dec = ctx2.decrypt(&rot, &keys2.sk);
        assert_eq!(ctx2.encoder().decode_slot(&dec, 0).to_i128(), Some(1));
        // A params blob that claims packed over a non-CRT-friendly t
        // must be rejected at parse time.
        let bad = params_to_json(&FvParams::custom(256, 2, 16));
        let mut bad = bad.to_string_json();
        bad = bad.replace("\"encoding\":\"scalar\"", "\"encoding\":\"packed\"");
        assert!(params_from_json(&Json::parse(&bad).unwrap()).is_err());
    }

    #[test]
    fn error_codes_roundtrip_and_display() {
        let all = [
            ErrorCode::BadVersion,
            ErrorCode::BadRequest,
            ErrorCode::AdmissionDenied,
            ErrorCode::Overloaded,
            ErrorCode::DeadlineExceeded,
            ErrorCode::UnknownJob,
            ErrorCode::JobFailed,
            ErrorCode::Internal,
            ErrorCode::Transport,
            ErrorCode::ShuttingDown,
        ];
        for code in all {
            assert_eq!(ErrorCode::from_str(code.as_str()), Some(code));
        }
        assert_eq!(ErrorCode::from_str("bogus"), None);
        // Retry policy: only transient conditions are retryable.
        let retryable: Vec<_> = all.iter().filter(|c| c.retryable()).collect();
        assert_eq!(retryable, [&ErrorCode::Overloaded, &ErrorCode::Transport]);
        let e = WireError::new(ErrorCode::Overloaded, "queue full");
        assert_eq!(e.to_string(), "[overloaded] queue full");
        // WireError implements std::error::Error, so `?` flattens it
        // into the repo-wide util::error::Error.
        let flat: crate::util::error::Error = e.into();
        assert!(flat.to_string().contains("overloaded"));
    }

    #[test]
    fn ct_codec_rejects_tampered_record() {
        let ctx = FvContext::new(FvParams::custom(256, 2, 16));
        let mut rng = ChaChaRng::from_seed(707);
        let keys = keygen(&ctx, &mut rng);
        let mut ct = ctx.encrypt(&encode_int(9, ctx.d()), &keys.pk, &mut rng);
        ct.ct_depth = 2;
        let text = ct_to_json(&ct).to_string_json();
        assert!(text.contains("\"crc\":\""), "records carry a checksum");
        assert!(text.contains("\"v\":1"), "records carry a version tag");
        // A tampered byte (depth flipped, polys untouched and still
        // canonical) fails the checksum with a structured error.
        let tampered = text.replacen("\"depth\":2", "\"depth\":1", 1);
        let err = ct_from_json(&ctx, &Json::parse(&tampered).unwrap()).unwrap_err();
        assert!(err.to_string().contains("checksum mismatch"), "{err}");
        // An unknown record version is rejected outright.
        let future = text.replacen("\"v\":1", "\"v\":9", 1);
        let err = ct_from_json(&ctx, &Json::parse(&future).unwrap()).unwrap_err();
        assert!(err.to_string().contains("version mismatch"), "{err}");
        // Legacy records (no v/crc) still parse.
        let mut legacy = Json::parse(&text).unwrap();
        if let Json::Obj(m) = &mut legacy {
            m.remove("crc");
            m.remove("v");
        }
        assert_eq!(ct_from_json(&ctx, &legacy).unwrap().polys, ct.polys);
    }

    #[test]
    fn fit_codec_rejects_truncated_record() {
        let ctx = FvContext::new(FvParams::custom(256, 2, 16));
        let mut rng = ChaChaRng::from_seed(708);
        let keys = keygen(&ctx, &mut rng);
        let betas: Vec<_> = [4i64, -7]
            .iter()
            .map(|&v| ctx.encrypt(&encode_int(v, ctx.d()), &keys.pk, &mut rng))
            .collect();
        let fit = EncryptedFit {
            betas,
            divisor: BigUint::from_u64(1234),
            path: None,
            phi: 2,
            paper_mmd: 4,
            noise_depth: 3,
        };
        let j = fit_to_json(&fit);
        let back = fit_from_json(&ctx, &j).unwrap();
        assert_eq!(back.betas.len(), 2);
        assert_eq!(back.betas[1].polys, fit.betas[1].polys);
        // Dropping a beta leaves every remaining ct intact but fails
        // the fit-level checksum — truncation is not silent.
        let mut truncated = j.clone();
        if let Json::Obj(m) = &mut truncated {
            if let Some(Json::Arr(b)) = m.get_mut("betas") {
                b.pop();
            }
        }
        let err = fit_from_json(&ctx, &truncated).unwrap_err();
        assert!(err.to_string().contains("checksum mismatch"), "{err}");
        // So does a tampered divisor.
        let bad = j.to_string_json().replacen("\"divisor\":\"1234\"", "\"divisor\":\"1235\"", 1);
        assert!(fit_from_json(&ctx, &Json::parse(&bad).unwrap()).is_err());
    }

    #[test]
    fn checkpoint_roundtrip_is_bit_exact() {
        use crate::els::encrypted::{CheckpointState, DescentCheckpoint};
        let ctx = FvContext::new(FvParams::custom(256, 2, 16));
        let mut rng = ChaChaRng::from_seed(709);
        let keys = keygen(&ctx, &mut rng);
        let mut enc = |v: i64| ctx.encrypt(&encode_int(v, ctx.d()), &keys.pk, &mut rng);
        let (b0, b1, r0) = (enc(3), enc(-5), enc(11));
        let gd = DescentCheckpoint {
            phi: 2,
            nu: 9,
            done: 1,
            state: CheckpointState::Gd {
                beta: vec![b0.clone(), b1.clone()],
                path: vec![vec![b0.clone(), b1.clone()]],
            },
        };
        let j = checkpoint_to_json(&gd).to_string_json();
        let back = checkpoint_from_json(&ctx, &Json::parse(&j).unwrap()).unwrap();
        assert_eq!((back.phi, back.nu, back.done), (2, 9, 1));
        let CheckpointState::Gd { beta, path } = &back.state else {
            panic!("state variant changed in roundtrip");
        };
        assert_eq!(beta[0].polys, b0.polys);
        assert_eq!(path[0][1].polys, b1.polys);
        // CD state: None coordinates survive as nulls.
        let cd = DescentCheckpoint {
            phi: 1,
            nu: 4,
            done: 1,
            state: CheckpointState::Cd {
                beta: vec![Some(b0.clone()), None],
                r: vec![r0.clone()],
            },
        };
        let j = checkpoint_to_json(&cd).to_string_json();
        let back = checkpoint_from_json(&ctx, &Json::parse(&j).unwrap()).unwrap();
        let CheckpointState::Cd { beta, r } = &back.state else {
            panic!("state variant changed in roundtrip");
        };
        assert_eq!(beta[0].as_ref().unwrap().polys, b0.polys);
        assert!(beta[1].is_none());
        assert_eq!(r[0].polys, r0.polys);
        assert!(checkpoint_from_json(
            &ctx,
            &Json::parse(&j.replacen("\"algo\":\"cd\"", "\"algo\":\"xx\"", 1)).unwrap()
        )
        .is_err());
    }

    #[test]
    fn cfg_roundtrip() {
        let cfg = FitConfig { iters: 5, nu: 42, accel: Accel::Vwt, keep_path: false };
        let j = cfg_to_json(&cfg, Some(7)).to_string_json();
        let (back, cd) = cfg_from_json(&Json::parse(&j).unwrap()).unwrap();
        assert_eq!(back.iters, 5);
        assert_eq!(back.nu, 42);
        assert_eq!(back.accel, Accel::Vwt);
        assert_eq!(cd, Some(7));
        assert!(accel_from_str("bogus").is_err());
    }
}
