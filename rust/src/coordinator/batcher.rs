//! Cross-job dynamic batching of homomorphic multiplications — the FHE
//! analogue of continuous batching in an LLM serving stack.
//!
//! [`BatchingEngine`] wraps any [`HeEngine`]: callers (one worker thread
//! per job) still see the synchronous `mul_pairs`/`dot_pairs` APIs, but
//! requests are funnelled to a dispatcher thread that coalesces work
//! from concurrent jobs up to `max_batch` pairs or `max_wait`, executes
//! one fused backend call, and scatters the results back. Small jobs
//! thus ride along with large ones instead of paying per-call dispatch
//! overhead (for the XLA backend: per-executable-launch overhead).
//!
//! The queue is **group-shaped**: the unit of work is one inner-product
//! group (`Σ_k a_k·b_k` → one ciphertext). A `mul_pairs` call enters
//! the same queue as singleton groups — exactly the product semantics,
//! and bit-identical through a fusing backend, since a one-pair fused
//! accumulation *is* the single multiply. One dispatch therefore mixes
//! plain products and fused sums from different jobs in a single
//! backend `dot_pairs` call.

use std::sync::atomic::Ordering;
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::fhe::{Ciphertext, FvContext, Plaintext, PlaintextNtt};
use crate::runtime::backend::{HeEngine, OpStats};
use crate::util::faults::{self, FaultSite};
use crate::util::telemetry::{self, Phase};

/// One coalesced dispatch's outcome per work item: the per-group
/// ciphertexts, or the failure message when the backend call died
/// (panic or injected fault). Failure fans out to *every* item in the
/// batch — the dispatcher itself always survives.
type DispatchReply = std::result::Result<Vec<Ciphertext>, String>;

struct WorkItem {
    /// Inner-product groups (singletons for plain products); the reply
    /// carries one ciphertext per group.
    groups: Vec<Vec<(Ciphertext, Ciphertext)>>,
    reply: Sender<DispatchReply>,
}

impl WorkItem {
    fn npairs(&self) -> usize {
        self.groups.iter().map(|g| g.len()).sum()
    }
}

/// Batching configuration.
#[derive(Clone, Copy, Debug)]
pub struct BatchConfig {
    /// Coalesce at most this many ciphertext pairs per backend call.
    pub max_batch: usize,
    /// Wait at most this long for more work before dispatching.
    pub max_wait: Duration,
}

impl Default for BatchConfig {
    fn default() -> Self {
        BatchConfig { max_batch: 64, max_wait: Duration::from_millis(2) }
    }
}

/// Dispatcher-side counters: how many fused backend calls ran, and how
/// many of them actually merged work items from more than one submit
/// call (i.e. cross-job coalescing happened, not just pass-through).
#[derive(Default)]
pub struct DispatchStats {
    pub dispatches: std::sync::atomic::AtomicU64,
    pub coalesced: std::sync::atomic::AtomicU64,
}

/// An [`HeEngine`] that coalesces `mul_pairs` calls across threads.
pub struct BatchingEngine {
    inner: Arc<dyn HeEngine>,
    tx: Mutex<Option<Sender<WorkItem>>>,
    handle: Mutex<Option<std::thread::JoinHandle<()>>>,
    stats: OpStats,
    dispatch: Arc<DispatchStats>,
}

impl BatchingEngine {
    pub fn new(inner: Arc<dyn HeEngine>, cfg: BatchConfig) -> Arc<Self> {
        let (tx, rx) = channel::<WorkItem>();
        let dispatch = Arc::new(DispatchStats::default());
        let engine = Arc::new(BatchingEngine {
            inner: inner.clone(),
            tx: Mutex::new(Some(tx)),
            handle: Mutex::new(None),
            stats: OpStats::default(),
            dispatch: Arc::clone(&dispatch),
        });
        let handle = std::thread::Builder::new()
            .name("els-batcher".into())
            .spawn(move || dispatcher(inner, rx, cfg, dispatch))
            .expect("spawning batcher");
        *engine.handle.lock().unwrap() = Some(handle);
        engine
    }

    /// `(dispatches, coalesced_dispatches)`: total fused backend calls
    /// and the subset that merged items from ≥ 2 submit calls.
    pub fn dispatch_counts(&self) -> (u64, u64) {
        (
            self.dispatch.dispatches.load(Ordering::Relaxed),
            self.dispatch.coalesced.load(Ordering::Relaxed),
        )
    }

    /// Enqueue one group-shaped work item and block for its replies
    /// (one ciphertext per group). A failed dispatch (backend panic or
    /// injected `batcher:fail` fault) panics on the *caller* thread —
    /// inside the coordinator's per-job `catch_unwind`, so it resolves
    /// to that job's `job_failed` while unrelated jobs and the
    /// dispatcher keep going.
    fn submit(&self, groups: Vec<Vec<(Ciphertext, Ciphertext)>>) -> Vec<Ciphertext> {
        let (reply_tx, reply_rx) = channel();
        let item = WorkItem { groups, reply: reply_tx };
        self.tx
            .lock()
            .unwrap()
            .as_ref()
            .expect("batcher already shut down")
            .send(item)
            .expect("batcher thread gone");
        match reply_rx.recv().expect("batcher dropped reply") {
            Ok(out) => out,
            Err(msg) => panic!("batch dispatch failed: {msg}"),
        }
    }

    /// Stop the dispatcher (drains pending work first).
    pub fn shutdown(&self) {
        let tx = self.tx.lock().unwrap().take();
        drop(tx);
        if let Some(h) = self.handle.lock().unwrap().take() {
            let _ = h.join();
        }
    }
}

impl Drop for BatchingEngine {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn dispatcher(
    inner: Arc<dyn HeEngine>,
    rx: Receiver<WorkItem>,
    cfg: BatchConfig,
    dispatch: Arc<DispatchStats>,
) {
    loop {
        // Block for the first item; exit when all senders are gone.
        let first = match rx.recv() {
            Ok(w) => w,
            Err(_) => return,
        };
        let mut items = vec![first];
        let mut total: usize = items[0].npairs();
        let deadline = Instant::now() + cfg.max_wait;
        while total < cfg.max_batch {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match rx.recv_timeout(deadline - now) {
                Ok(w) => {
                    total += w.npairs();
                    items.push(w);
                }
                Err(RecvTimeoutError::Timeout) => break,
                Err(RecvTimeoutError::Disconnected) => break,
            }
        }
        dispatch.dispatches.fetch_add(1, Ordering::Relaxed);
        if items.len() > 1 {
            dispatch.coalesced.fetch_add(1, Ordering::Relaxed);
        }
        // One fused backend call over every coalesced group (plain
        // products ride along as singleton groups).
        let group_refs: Vec<Vec<(&Ciphertext, &Ciphertext)>> = items
            .iter()
            .flat_map(|w| {
                w.groups.iter().map(|g| g.iter().map(|(a, b)| (a, b)).collect())
            })
            .collect();
        let all_groups: Vec<&[(&Ciphertext, &Ciphertext)]> =
            group_refs.iter().map(|g| g.as_slice()).collect();
        // Chaos `batcher:fail` injects a dispatch failure; a real
        // backend panic is caught the same way. Either way the
        // dispatcher thread survives and the failure is *scattered* to
        // every waiting item — a dead dispatcher would instead cascade
        // "batcher dropped reply" panics into all future jobs.
        let outcome: std::result::Result<Vec<Ciphertext>, String> =
            if faults::check(FaultSite::Batcher).is_some() {
                Err("injected batcher dispatch failure".to_string())
            } else {
                let _span = telemetry::span(Phase::BatchDispatch);
                std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                    inner.dot_pairs(&all_groups)
                }))
                .map_err(|e| {
                    e.downcast_ref::<String>()
                        .cloned()
                        .or_else(|| e.downcast_ref::<&str>().map(|s| s.to_string()))
                        .unwrap_or_else(|| "backend panicked in dispatch".to_string())
                })
            };
        match outcome {
            Ok(results) => {
                let mut results = results.into_iter();
                for item in &items {
                    let n = item.groups.len();
                    let out: Vec<Ciphertext> = results.by_ref().take(n).collect();
                    // Receiver may have given up (job failed) — ignore.
                    let _ = item.reply.send(Ok(out));
                }
            }
            Err(msg) => {
                for item in &items {
                    let _ = item.reply.send(Err(msg.clone()));
                }
            }
        }
    }
}

impl HeEngine for BatchingEngine {
    fn ctx(&self) -> &FvContext {
        self.inner.ctx()
    }

    fn stats(&self) -> &OpStats {
        &self.stats
    }

    fn mul_pairs(&self, pairs: &[(&Ciphertext, &Ciphertext)]) -> Vec<Ciphertext> {
        if pairs.is_empty() {
            return Vec::new();
        }
        self.stats.ct_muls.fetch_add(pairs.len() as u64, Ordering::Relaxed);
        self.stats.batches.fetch_add(1, Ordering::Relaxed);
        // Each product is a singleton group: identical semantics (and,
        // through a fusing backend, identical bits) to a flat
        // mul_pairs, while sharing the dispatcher with fused sums.
        self.submit(
            pairs.iter().map(|&(a, b)| vec![(a.clone(), b.clone())]).collect(),
        )
    }

    fn dot_pairs(&self, groups: &[&[(&Ciphertext, &Ciphertext)]]) -> Vec<Ciphertext> {
        if groups.is_empty() {
            return Vec::new();
        }
        // Enforce the non-empty-group precondition on the *caller*
        // thread: letting it trip inside the shared dispatcher would
        // kill the dispatcher and cascade 'batcher dropped reply'
        // panics into every unrelated concurrent job.
        for (i, g) in groups.iter().enumerate() {
            assert!(!g.is_empty(), "dot_pairs group {i} must be non-empty");
        }
        let total: u64 = groups.iter().map(|g| g.len() as u64).sum();
        self.stats.ct_muls.fetch_add(total, Ordering::Relaxed);
        self.stats.batches.fetch_add(1, Ordering::Relaxed);
        self.submit(
            groups
                .iter()
                .map(|g| g.iter().map(|&(a, b)| (a.clone(), b.clone())).collect())
                .collect(),
        )
    }

    fn mul_plain(&self, a: &Ciphertext, pt: &Plaintext) -> Ciphertext {
        // Plaintext muls are cheap; run them inline on the caller thread.
        self.stats.plain_muls.fetch_add(1, Ordering::Relaxed);
        self.inner.ctx().mul_plain(a, pt)
    }

    fn mul_plain_prepared(&self, a: &Ciphertext, m: &PlaintextNtt) -> Ciphertext {
        // Cached-operand plaintext muls are pure pointwise passes —
        // inline on the caller thread, never through the dispatcher.
        self.stats.plain_muls.fetch_add(1, Ordering::Relaxed);
        self.inner.ctx().mul_plain_prepared(a, m)
    }

    fn rotate_rows(
        &self,
        ct: &Ciphertext,
        steps: usize,
    ) -> crate::util::error::Result<Ciphertext> {
        // Rotations are single key switches — cheap next to the fused
        // mul pipeline; forward inline to the wrapped engine (which
        // holds the Galois keys), never through the dispatcher.
        self.inner.rotate_rows(ct, steps)
    }

    fn slot_sum(&self, ct: &Ciphertext) -> crate::util::error::Result<Ciphertext> {
        self.inner.slot_sum(ct)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fhe::encoding::encode_int;
    use crate::fhe::keys::keygen;
    use crate::fhe::params::FvParams;
    use crate::fhe::rng::ChaChaRng;
    use crate::fhe::FvContext;
    use crate::runtime::backend::NativeEngine;

    fn setup() -> (Arc<FvContext>, crate::fhe::KeySet, Arc<BatchingEngine>) {
        let ctx = FvContext::new(FvParams::custom(256, 3, 24));
        let mut rng = ChaChaRng::from_seed(501);
        let keys = keygen(&ctx, &mut rng);
        let native = Arc::new(NativeEngine::new(ctx.clone(), Arc::new(keys.rk.clone())));
        let engine = BatchingEngine::new(native, BatchConfig::default());
        (ctx, keys, engine)
    }

    #[test]
    fn coalesces_across_threads() {
        let (ctx, keys, engine) = setup();
        let mut rng = ChaChaRng::from_seed(502);
        // Encrypt operands for 4 threads × 3 muls.
        let mut jobs = Vec::new();
        for t in 0..4i64 {
            let cts: Vec<(Ciphertext, Ciphertext, i64)> = (1..=3i64)
                .map(|k| {
                    let a = 10 * t + k;
                    let b = k - 2;
                    (
                        ctx.encrypt(&encode_int(a, ctx.d()), &keys.pk, &mut rng),
                        ctx.encrypt(&encode_int(b, ctx.d()), &keys.pk, &mut rng),
                        a * b,
                    )
                })
                .collect();
            jobs.push(cts);
        }
        let outputs: Vec<Vec<(Ciphertext, i64)>> = std::thread::scope(|s| {
            let handles: Vec<_> = jobs
                .iter()
                .map(|cts| {
                    let engine = engine.clone();
                    s.spawn(move || {
                        let pairs: Vec<(&Ciphertext, &Ciphertext)> =
                            cts.iter().map(|(a, b, _)| (a, b)).collect();
                        let out = engine.mul_pairs(&pairs);
                        out.into_iter()
                            .zip(cts.iter().map(|(_, _, e)| *e))
                            .collect::<Vec<_>>()
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        for outs in outputs {
            for (ct, expect) in outs {
                let pt = ctx.decrypt(&ct, &keys.sk);
                assert_eq!(pt.eval_at_2().to_i128(), Some(expect as i128));
            }
        }
        engine.shutdown();
    }

    #[test]
    fn coalesces_groups_and_singletons_across_threads() {
        // Mixed workload: two threads submit fused inner-product
        // groups, two submit plain mul_pairs; all four coalesce into
        // shared dispatches and every job gets its own sums back.
        let (ctx, keys, engine) = setup();
        let mut rng = ChaChaRng::from_seed(503);
        let enc = |v: i64, rng: &mut ChaChaRng| {
            ctx.encrypt(&encode_int(v, ctx.d()), &keys.pk, rng)
        };
        // Per dot-thread: one group of 3 pairs + one group of 2.
        let dot_jobs: Vec<(Vec<Vec<(Ciphertext, Ciphertext)>>, Vec<i64>)> = (0..2i64)
            .map(|t| {
                let mut groups = Vec::new();
                let mut expects = Vec::new();
                for (gi, len) in [3usize, 2].into_iter().enumerate() {
                    let mut group = Vec::new();
                    let mut sum = 0i64;
                    for k in 0..len as i64 {
                        let a = 5 * t + k + gi as i64;
                        let b = 3 - k;
                        sum += a * b;
                        group.push((enc(a, &mut rng), enc(b, &mut rng)));
                    }
                    groups.push(group);
                    expects.push(sum);
                }
                (groups, expects)
            })
            .collect();
        let mul_jobs: Vec<Vec<(Ciphertext, Ciphertext, i64)>> = (0..2i64)
            .map(|t| {
                (1..=2i64)
                    .map(|k| {
                        let (a, b) = (7 * t + k, k - 1);
                        (enc(a, &mut rng), enc(b, &mut rng), a * b)
                    })
                    .collect()
            })
            .collect();
        std::thread::scope(|s| {
            let mut handles = Vec::new();
            for (groups, expects) in &dot_jobs {
                let engine = engine.clone();
                handles.push(s.spawn(move || {
                    let refs: Vec<Vec<(&Ciphertext, &Ciphertext)>> = groups
                        .iter()
                        .map(|g| g.iter().map(|(a, b)| (a, b)).collect())
                        .collect();
                    let slices: Vec<&[(&Ciphertext, &Ciphertext)]> =
                        refs.iter().map(|g| g.as_slice()).collect();
                    let out = engine.dot_pairs(&slices);
                    (out, expects.clone())
                }));
            }
            for cts in &mul_jobs {
                let engine = engine.clone();
                handles.push(s.spawn(move || {
                    let pairs: Vec<(&Ciphertext, &Ciphertext)> =
                        cts.iter().map(|(a, b, _)| (a, b)).collect();
                    let out = engine.mul_pairs(&pairs);
                    (out, cts.iter().map(|(_, _, e)| *e).collect())
                }));
            }
            for h in handles {
                let (out, expects) = h.join().unwrap();
                assert_eq!(out.len(), expects.len());
                for (ct, expect) in out.iter().zip(expects) {
                    let pt = ctx.decrypt(ct, &keys.sk);
                    assert_eq!(pt.eval_at_2().to_i128(), Some(expect as i128));
                }
            }
        });
        engine.shutdown();
    }

    #[test]
    fn empty_group_panics_on_the_caller_not_the_dispatcher() {
        // The precondition fires on the submitting thread; the shared
        // dispatcher (and other jobs' replies) must stay alive.
        let (ctx, keys, engine) = setup();
        let mut rng = ChaChaRng::from_seed(504);
        let a = ctx.encrypt(&encode_int(3, ctx.d()), &keys.pk, &mut rng);
        let b = ctx.encrypt(&encode_int(4, ctx.d()), &keys.pk, &mut rng);
        let bad = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            engine.dot_pairs(&[&[][..]])
        }));
        assert!(bad.is_err(), "empty group must panic");
        // The dispatcher survived: a valid job still completes.
        let out = engine.dot_pairs(&[&[(&a, &b)][..]]);
        assert_eq!(ctx.decrypt(&out[0], &keys.sk).eval_at_2().to_i128(), Some(12));
        engine.shutdown();
    }

    #[test]
    fn cross_job_coalescing_is_bit_identical_to_solo_execution() {
        // Three "jobs" (threads) each submit a dot_pairs call; the
        // batch size equals the exact total pair count, so the
        // dispatcher provably blocks until all three jobs' groups are
        // merged into ONE backend call. Each job's results must be
        // bit-identical to running its groups alone on the bare native
        // engine — batch composition never changes bits.
        let ctx = FvContext::new(FvParams::custom(256, 3, 24));
        let mut rng = ChaChaRng::from_seed(505);
        let keys = keygen(&ctx, &mut rng);
        let native = Arc::new(NativeEngine::new(ctx.clone(), Arc::new(keys.rk.clone())));
        // Jobs: group shapes (3+2), (2), (1+1+2) = 11 pairs total.
        let shapes: [&[usize]; 3] = [&[3, 2], &[2], &[1, 1, 2]];
        let total_pairs: usize = shapes.iter().flat_map(|s| s.iter()).sum();
        let engine = BatchingEngine::new(
            native.clone(),
            BatchConfig { max_batch: total_pairs, max_wait: Duration::from_secs(2) },
        );
        let jobs: Vec<Vec<Vec<(Ciphertext, Ciphertext)>>> = shapes
            .iter()
            .enumerate()
            .map(|(t, shape)| {
                shape
                    .iter()
                    .enumerate()
                    .map(|(gi, &len)| {
                        (0..len as i64)
                            .map(|k| {
                                let a = 9 * t as i64 + 2 * gi as i64 + k + 1;
                                let b = k - 1;
                                (
                                    ctx.encrypt(&encode_int(a, ctx.d()), &keys.pk, &mut rng),
                                    ctx.encrypt(&encode_int(b, ctx.d()), &keys.pk, &mut rng),
                                )
                            })
                            .collect()
                    })
                    .collect()
            })
            .collect();
        // Solo reference: each job alone on the bare engine.
        let solo: Vec<Vec<Ciphertext>> = jobs
            .iter()
            .map(|groups| {
                let refs: Vec<Vec<(&Ciphertext, &Ciphertext)>> = groups
                    .iter()
                    .map(|g| g.iter().map(|(a, b)| (a, b)).collect())
                    .collect();
                let slices: Vec<&[(&Ciphertext, &Ciphertext)]> =
                    refs.iter().map(|g| g.as_slice()).collect();
                native.dot_pairs(&slices)
            })
            .collect();
        // Concurrent: all three jobs through the coalescing batcher.
        let merged: Vec<Vec<Ciphertext>> = std::thread::scope(|s| {
            let handles: Vec<_> = jobs
                .iter()
                .map(|groups| {
                    let engine = engine.clone();
                    s.spawn(move || {
                        let refs: Vec<Vec<(&Ciphertext, &Ciphertext)>> = groups
                            .iter()
                            .map(|g| g.iter().map(|(a, b)| (a, b)).collect())
                            .collect();
                        let slices: Vec<&[(&Ciphertext, &Ciphertext)]> =
                            refs.iter().map(|g| g.as_slice()).collect();
                        engine.dot_pairs(&slices)
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        let (dispatches, coalesced) = engine.dispatch_counts();
        assert_eq!(dispatches, 1, "expected one merged dispatch, saw {dispatches}");
        assert_eq!(coalesced, 1, "the single dispatch must span multiple jobs");
        for (job_solo, job_merged) in solo.iter().zip(&merged) {
            assert_eq!(job_solo.len(), job_merged.len());
            for (a, b) in job_solo.iter().zip(job_merged) {
                assert_eq!(a.polys, b.polys, "coalescing changed job results");
                assert_eq!(a.ct_depth, b.ct_depth);
            }
        }
        engine.shutdown();
    }

    #[test]
    fn injected_dispatch_failure_panics_caller_and_dispatcher_survives() {
        use crate::util::faults::{FaultKind, FaultSession, FaultSite, FaultSpec};
        let (ctx, keys, engine) = setup();
        let mut rng = ChaChaRng::from_seed(506);
        let a = ctx.encrypt(&encode_int(3, ctx.d()), &keys.pk, &mut rng);
        let b = ctx.encrypt(&encode_int(5, ctx.d()), &keys.pk, &mut rng);
        {
            let _chaos = FaultSession::activate(&[FaultSpec {
                site: FaultSite::Batcher,
                kind: FaultKind::Fail,
                rate: 1.0,
                seed: 31,
            }]);
            let failed = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                engine.dot_pairs(&[&[(&a, &b)][..]])
            }));
            let msg = match failed {
                Err(e) => e
                    .downcast_ref::<String>()
                    .cloned()
                    .expect("panic payload should be a String"),
                Ok(_) => panic!("rate-1.0 dispatch fault must fail the call"),
            };
            assert!(msg.contains("batch dispatch failed"), "{msg}");
        }
        // Session over: the dispatcher is still alive and correct.
        let out = engine.dot_pairs(&[&[(&a, &b)][..]]);
        assert_eq!(ctx.decrypt(&out[0], &keys.sk).eval_at_2().to_i128(), Some(15));
        engine.shutdown();
    }

    #[test]
    fn shutdown_is_idempotent() {
        let (_, _, engine) = setup();
        engine.shutdown();
        engine.shutdown();
    }
}
