//! Cross-job dynamic batching of homomorphic multiplications — the FHE
//! analogue of continuous batching in an LLM serving stack.
//!
//! [`BatchingEngine`] wraps any [`HeEngine`]: callers (one worker thread
//! per job) still see the synchronous `mul_pairs` API, but requests are
//! funnelled to a dispatcher thread that coalesces work from concurrent
//! jobs up to `max_batch` pairs or `max_wait`, executes one fused
//! backend call, and scatters the results back. Small jobs thus ride
//! along with large ones instead of paying per-call dispatch overhead
//! (for the XLA backend: per-executable-launch overhead).

use std::sync::atomic::Ordering;
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::fhe::{Ciphertext, FvContext, Plaintext, PlaintextNtt};
use crate::runtime::backend::{HeEngine, OpStats};

struct WorkItem {
    pairs: Vec<(Ciphertext, Ciphertext)>,
    reply: Sender<Vec<Ciphertext>>,
}

/// Batching configuration.
#[derive(Clone, Copy, Debug)]
pub struct BatchConfig {
    /// Coalesce at most this many ciphertext pairs per backend call.
    pub max_batch: usize,
    /// Wait at most this long for more work before dispatching.
    pub max_wait: Duration,
}

impl Default for BatchConfig {
    fn default() -> Self {
        BatchConfig { max_batch: 64, max_wait: Duration::from_millis(2) }
    }
}

/// An [`HeEngine`] that coalesces `mul_pairs` calls across threads.
pub struct BatchingEngine {
    inner: Arc<dyn HeEngine>,
    tx: Mutex<Option<Sender<WorkItem>>>,
    handle: Mutex<Option<std::thread::JoinHandle<()>>>,
    stats: OpStats,
}

impl BatchingEngine {
    pub fn new(inner: Arc<dyn HeEngine>, cfg: BatchConfig) -> Arc<Self> {
        let (tx, rx) = channel::<WorkItem>();
        let engine = Arc::new(BatchingEngine {
            inner: inner.clone(),
            tx: Mutex::new(Some(tx)),
            handle: Mutex::new(None),
            stats: OpStats::default(),
        });
        let handle = std::thread::Builder::new()
            .name("els-batcher".into())
            .spawn(move || dispatcher(inner, rx, cfg))
            .expect("spawning batcher");
        *engine.handle.lock().unwrap() = Some(handle);
        engine
    }

    /// Stop the dispatcher (drains pending work first).
    pub fn shutdown(&self) {
        let tx = self.tx.lock().unwrap().take();
        drop(tx);
        if let Some(h) = self.handle.lock().unwrap().take() {
            let _ = h.join();
        }
    }
}

impl Drop for BatchingEngine {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn dispatcher(inner: Arc<dyn HeEngine>, rx: Receiver<WorkItem>, cfg: BatchConfig) {
    loop {
        // Block for the first item; exit when all senders are gone.
        let first = match rx.recv() {
            Ok(w) => w,
            Err(_) => return,
        };
        let mut items = vec![first];
        let mut total: usize = items[0].pairs.len();
        let deadline = Instant::now() + cfg.max_wait;
        while total < cfg.max_batch {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match rx.recv_timeout(deadline - now) {
                Ok(w) => {
                    total += w.pairs.len();
                    items.push(w);
                }
                Err(RecvTimeoutError::Timeout) => break,
                Err(RecvTimeoutError::Disconnected) => break,
            }
        }
        // One fused backend call over every coalesced pair.
        let all_pairs: Vec<(&Ciphertext, &Ciphertext)> = items
            .iter()
            .flat_map(|w| w.pairs.iter().map(|(a, b)| (a, b)))
            .collect();
        let mut results = inner.mul_pairs(&all_pairs).into_iter();
        for item in &items {
            let n = item.pairs.len();
            let out: Vec<Ciphertext> = results.by_ref().take(n).collect();
            // Receiver may have given up (job failed) — ignore.
            let _ = item.reply.send(out);
        }
    }
}

impl HeEngine for BatchingEngine {
    fn ctx(&self) -> &FvContext {
        self.inner.ctx()
    }

    fn stats(&self) -> &OpStats {
        &self.stats
    }

    fn mul_pairs(&self, pairs: &[(&Ciphertext, &Ciphertext)]) -> Vec<Ciphertext> {
        if pairs.is_empty() {
            return Vec::new();
        }
        self.stats.ct_muls.fetch_add(pairs.len() as u64, Ordering::Relaxed);
        self.stats.batches.fetch_add(1, Ordering::Relaxed);
        let (reply_tx, reply_rx) = channel();
        let item = WorkItem {
            pairs: pairs.iter().map(|(a, b)| ((*a).clone(), (*b).clone())).collect(),
            reply: reply_tx,
        };
        self.tx
            .lock()
            .unwrap()
            .as_ref()
            .expect("batcher already shut down")
            .send(item)
            .expect("batcher thread gone");
        reply_rx.recv().expect("batcher dropped reply")
    }

    fn mul_plain(&self, a: &Ciphertext, pt: &Plaintext) -> Ciphertext {
        // Plaintext muls are cheap; run them inline on the caller thread.
        self.stats.plain_muls.fetch_add(1, Ordering::Relaxed);
        self.inner.ctx().mul_plain(a, pt)
    }

    fn mul_plain_prepared(&self, a: &Ciphertext, m: &PlaintextNtt) -> Ciphertext {
        // Cached-operand plaintext muls are pure pointwise passes —
        // inline on the caller thread, never through the dispatcher.
        self.stats.plain_muls.fetch_add(1, Ordering::Relaxed);
        self.inner.ctx().mul_plain_prepared(a, m)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fhe::encoding::encode_int;
    use crate::fhe::keys::keygen;
    use crate::fhe::params::FvParams;
    use crate::fhe::rng::ChaChaRng;
    use crate::fhe::FvContext;
    use crate::runtime::backend::NativeEngine;

    fn setup() -> (Arc<FvContext>, crate::fhe::KeySet, Arc<BatchingEngine>) {
        let ctx = FvContext::new(FvParams::custom(256, 3, 24));
        let mut rng = ChaChaRng::from_seed(501);
        let keys = keygen(&ctx, &mut rng);
        let native = Arc::new(NativeEngine::new(ctx.clone(), Arc::new(keys.rk.clone())));
        let engine = BatchingEngine::new(native, BatchConfig::default());
        (ctx, keys, engine)
    }

    #[test]
    fn coalesces_across_threads() {
        let (ctx, keys, engine) = setup();
        let mut rng = ChaChaRng::from_seed(502);
        // Encrypt operands for 4 threads × 3 muls.
        let mut jobs = Vec::new();
        for t in 0..4i64 {
            let cts: Vec<(Ciphertext, Ciphertext, i64)> = (1..=3i64)
                .map(|k| {
                    let a = 10 * t + k;
                    let b = k - 2;
                    (
                        ctx.encrypt(&encode_int(a, ctx.d()), &keys.pk, &mut rng),
                        ctx.encrypt(&encode_int(b, ctx.d()), &keys.pk, &mut rng),
                        a * b,
                    )
                })
                .collect();
            jobs.push(cts);
        }
        let outputs: Vec<Vec<(Ciphertext, i64)>> = std::thread::scope(|s| {
            let handles: Vec<_> = jobs
                .iter()
                .map(|cts| {
                    let engine = engine.clone();
                    s.spawn(move || {
                        let pairs: Vec<(&Ciphertext, &Ciphertext)> =
                            cts.iter().map(|(a, b, _)| (a, b)).collect();
                        let out = engine.mul_pairs(&pairs);
                        out.into_iter()
                            .zip(cts.iter().map(|(_, _, e)| *e))
                            .collect::<Vec<_>>()
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        for outs in outputs {
            for (ct, expect) in outs {
                let pt = ctx.decrypt(&ct, &keys.sk);
                assert_eq!(pt.eval_at_2().to_i128(), Some(expect as i128));
            }
        }
        engine.shutdown();
    }

    #[test]
    fn shutdown_is_idempotent() {
        let (_, _, engine) = setup();
        engine.shutdown();
        engine.shutdown();
    }
}
