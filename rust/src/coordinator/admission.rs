//! Admission control: §4.5 as a runtime guardrail (a job is accepted
//! only if the compiled parameter set provably supports it — enough
//! noise budget for its multiplicative depth, a plaintext modulus large
//! enough for its Lemma-3 message growth, and ring room for the message
//! degree; rejections carry the parameter set the planner would need),
//! plus a load/deadline dimension ([`admit_load`]): bounded queues and
//! an up-front feasibility check against the observed service rate, so
//! overload surfaces as structured `Overloaded`/`DeadlineExceeded`
//! rejections instead of unbounded queue growth.

use crate::util::error::{bail, Result};

use crate::coordinator::protocol::{ErrorCode, WireError, WireResult};
use crate::els::encrypted::Accel;
use crate::els::mmd;
use crate::fhe::params::{per_level_noise_bits, plan, Algo, FvParams, PlanRequest};

/// Conservative estimate of the ct-mult depth a parameter set supports
/// (inverse of the planner's sizing formula).
pub fn supported_depth(params: &FvParams, msg_const_bits: usize) -> u32 {
    let t_bits = params.t.bit_len();
    let log_d = params.d.trailing_zeros() as usize;
    // Fresh invariant noise ≈ t·2d·B ⇒ t_bits + log d + ~4 bits.
    let fresh = t_bits + log_d + 4;
    // Per-level consumption: shared with the planner (fhe::params).
    let per_level = per_level_noise_bits(t_bits, params.d, msg_const_bits);
    let q_bits = params.q_bits();
    if q_bits <= fresh {
        return 0;
    }
    ((q_bits - fresh) / per_level) as u32
}

/// Description of a fit request for admission purposes.
#[derive(Clone, Debug)]
pub struct AdmissionRequest {
    pub n_obs: usize,
    pub p_vars: usize,
    pub iters: usize,
    pub phi: u32,
    pub nu: u64,
    pub accel: Accel,
    pub cd_updates: Option<usize>,
}

impl AdmissionRequest {
    fn plan_request(&self) -> PlanRequest {
        let algo = match (self.cd_updates, self.accel) {
            (Some(_), _) => Algo::Cd,
            (None, Accel::None) => Algo::Gd,
            (None, Accel::Vwt) => Algo::GdVwt,
            (None, Accel::Nag) => Algo::Nag,
        };
        let mut req = PlanRequest::gd(self.n_obs, self.p_vars, self.iters, self.phi, self.nu)
            .with_algo(algo);
        if self.accel == Accel::Nag {
            req.eta_abs_q =
                crate::els::scaling::NagScaling::new(self.phi, self.nu, self.iters).eta_abs();
        }
        req
    }

    /// Depth the job consumes.
    pub fn noise_depth(&self) -> u32 {
        match self.cd_updates {
            Some(u) => mmd::noise_depth_cd(u),
            None => mmd::noise_depth(self.iters),
        }
    }

    /// Paper Table-1 MMD (reported in job metadata).
    pub fn paper_mmd(&self) -> u32 {
        match self.cd_updates {
            Some(u) => mmd::paper_mmd_cd(u.div_ceil(self.p_vars.max(1)), self.p_vars),
            None => mmd::paper_mmd(self.accel, self.iters),
        }
    }
}

/// Admit or reject a request against a parameter set. On rejection the
/// error message includes the parameters the planner proposes.
pub fn admit(params: &FvParams, req: &AdmissionRequest) -> Result<()> {
    let preq = req.plan_request();
    let growth = preq.growth();
    // Message coefficients must fit t symmetrically.
    let t_need = growth.coeff_bound.mul_u64(2).add_u64(1);
    if params.t.cmp_big(&t_need) == std::cmp::Ordering::Less {
        let proposal = plan(&preq)?;
        bail!(
            "rejected: plaintext modulus too small (t has {} bits, Lemma-3 \
             growth needs {}); planner proposes d={}, {} q-primes, t_bits={}",
            params.t.bit_len(),
            t_need.bit_len(),
            proposal.d,
            proposal.q_count,
            proposal.t.bit_len()
        );
    }
    // Message degree must fit the ring.
    if growth.deg_bound + 8 > params.d {
        let proposal = plan(&preq)?;
        bail!(
            "rejected: message degree bound {} exceeds ring degree {}; \
             planner proposes d={}",
            growth.deg_bound,
            params.d,
            proposal.d
        );
    }
    // Noise depth must fit the modulus budget.
    let const_bits = 64 - (growth.max_const_l1.max(1) - 1).leading_zeros() as usize;
    let have = supported_depth(params, const_bits);
    let need = req.noise_depth();
    if need > have {
        let proposal = plan(&preq)?;
        bail!(
            "rejected: needs {} ct-mult levels, parameters support ~{}; \
             planner proposes d={}, {} q-primes",
            need,
            have,
            proposal.d,
            proposal.q_count
        );
    }
    Ok(())
}

/// The coordinator's instantaneous load, as seen at submit time.
#[derive(Clone, Copy, Debug)]
pub struct LoadState {
    /// Jobs queued but not yet picked up by a lane.
    pub pending: usize,
    /// Jobs currently executing on lanes.
    pub running: usize,
    /// Execution lane count.
    pub lanes: usize,
    /// Pending-queue capacity (jobs beyond this are `Overloaded`).
    pub queue_capacity: usize,
    /// Observed mean job latency (0.0 until the first completion).
    pub mean_latency_ms: f64,
}

impl LoadState {
    /// Optimistic wait+service estimate for a job entering the queue
    /// now: everything ahead of it plus itself, spread across the
    /// lanes, at the observed mean service time. Deliberately crude —
    /// it only has to catch deadlines that are *already* infeasible at
    /// submit, so the client learns before shipping ciphertexts into a
    /// queue that cannot serve them in time.
    pub fn estimated_ms(&self) -> f64 {
        let depth = (self.pending + self.running + 1) as f64;
        self.mean_latency_ms * depth / self.lanes.max(1) as f64
    }
}

/// Load/deadline admission: the second dimension beyond noise depth.
/// Returns a structured code — `Overloaded` when the pending queue is
/// at capacity, `DeadlineExceeded` when the requested deadline is
/// already infeasible given the observed service rate.
pub fn admit_load(load: &LoadState, deadline_ms: Option<u64>) -> WireResult<()> {
    if load.pending >= load.queue_capacity {
        return Err(WireError::new(
            ErrorCode::Overloaded,
            format!(
                "pending queue at capacity ({} of {}); resubmit later",
                load.pending, load.queue_capacity
            ),
        ));
    }
    if let Some(deadline) = deadline_ms {
        let estimate = load.estimated_ms();
        if estimate > deadline as f64 {
            return Err(WireError::new(
                ErrorCode::DeadlineExceeded,
                format!(
                    "deadline {deadline}ms infeasible: estimated completion \
                     {estimate:.0}ms ({} pending + {} running on {} lanes, \
                     mean {:.1}ms/job)",
                    load.pending, load.running, load.lanes, load.mean_latency_ms
                ),
            ));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(iters: usize) -> AdmissionRequest {
        AdmissionRequest {
            n_obs: 8,
            p_vars: 2,
            iters,
            phi: 2,
            nu: 16,
            accel: Accel::None,
            cd_updates: None,
        }
    }

    #[test]
    fn planned_params_admit_their_own_request() {
        for iters in [1usize, 2, 3] {
            let r = req(iters);
            let params = plan(
                &PlanRequest::gd(r.n_obs, r.p_vars, r.iters, r.phi, r.nu),
            )
            .unwrap();
            admit(&params, &r).unwrap_or_else(|e| panic!("iters={iters}: {e}"));
        }
    }

    #[test]
    fn undersized_params_rejected_with_proposal() {
        let params = FvParams::custom(256, 2, 12); // tiny t, tiny q
        let err = admit(&params, &req(3)).unwrap_err().to_string();
        assert!(err.contains("rejected"), "{err}");
        assert!(err.contains("planner proposes"), "{err}");
    }

    #[test]
    fn deeper_jobs_need_more() {
        let r1 = req(1);
        let params1 =
            plan(&PlanRequest::gd(r1.n_obs, r1.p_vars, 1, r1.phi, r1.nu)).unwrap();
        admit(&params1, &r1).unwrap();
        // The same params must reject a much deeper job.
        assert!(admit(&params1, &req(8)).is_err());
    }

    #[test]
    fn cd_consumes_p_times_depth() {
        let mut r = req(2);
        r.cd_updates = Some(2 * r.p_vars);
        assert_eq!(r.noise_depth(), mmd::noise_depth_cd(4));
        assert_eq!(r.paper_mmd(), 8); // 2·K·P with K=2 sweeps, P=2
    }

    #[test]
    fn supported_depth_monotone_in_q() {
        let small = FvParams::custom(256, 3, 20);
        let large = FvParams::custom(256, 6, 20);
        assert!(supported_depth(&large, 8) > supported_depth(&small, 8));
    }

    #[test]
    fn load_admission_codes() {
        let mut load = LoadState {
            pending: 0,
            running: 0,
            lanes: 2,
            queue_capacity: 4,
            mean_latency_ms: 100.0,
        };
        // Idle queue, no deadline: always admitted.
        admit_load(&load, None).unwrap();
        // Feasible deadline: one job on an idle 2-lane pool ≈ 50ms.
        admit_load(&load, Some(1000)).unwrap();
        // Infeasible deadline: 9 jobs deep at 100ms/job on 2 lanes.
        load.pending = 4;
        let full = admit_load(&load, Some(60)).unwrap_err();
        assert_eq!(full.code, ErrorCode::Overloaded, "{full}");
        load.pending = 3;
        load.running = 5;
        let late = admit_load(&load, Some(60)).unwrap_err();
        assert_eq!(late.code, ErrorCode::DeadlineExceeded, "{late}");
        // Best-effort jobs only bounce on queue capacity, never on the
        // latency estimate.
        admit_load(&load, None).unwrap();
    }

    #[test]
    fn load_admission_with_no_history_admits_any_deadline() {
        // Until the first completion the mean is 0 — the estimator has
        // no signal, so even a 0ms deadline is admitted here and left
        // to the queue-side expiry check.
        let load = LoadState {
            pending: 2,
            running: 2,
            lanes: 1,
            queue_capacity: 8,
            mean_latency_ms: 0.0,
        };
        admit_load(&load, Some(0)).unwrap();
    }
}
