//! Encrypted regression jobs: specs, lifecycle state, timing.

use std::time::{Duration, Instant};

use crate::els::encrypted::{EncryptedFit, FitConfig};
use crate::els::model::EncryptedDataset;

/// Job identifier.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct JobId(pub u64);

impl std::fmt::Display for JobId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "job-{}", self.0)
    }
}

/// What to fit.
pub struct JobSpec {
    pub data: EncryptedDataset,
    pub cfg: FitConfig,
    /// If set, run ELS-CD with this many coordinate updates instead of
    /// the GD family (used by the fig2 comparison workloads).
    pub cd_updates: Option<usize>,
}

/// Lifecycle.
pub enum JobState {
    Queued,
    Running,
    Done(EncryptedFit),
    Failed(String),
}

impl JobState {
    pub fn label(&self) -> &'static str {
        match self {
            JobState::Queued => "queued",
            JobState::Running => "running",
            JobState::Done(_) => "done",
            JobState::Failed(_) => "failed",
        }
    }
}

/// A tracked job.
pub struct Job {
    pub id: JobId,
    pub state: JobState,
    pub submitted: Instant,
    pub finished: Option<Instant>,
}

impl Job {
    pub fn new(id: JobId) -> Self {
        Job { id, state: JobState::Queued, submitted: Instant::now(), finished: None }
    }

    pub fn latency(&self) -> Option<Duration> {
        self.finished.map(|f| f - self.submitted)
    }
}
