//! Encrypted regression jobs: specs, lifecycle state, timing.

use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::coordinator::tenant::TenantId;
use crate::els::encrypted::{EncryptedFit, FitConfig};
use crate::els::model::EncryptedDataset;
use crate::runtime::exec::Event;

/// Job identifier.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct JobId(pub u64);

impl std::fmt::Display for JobId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "job-{}", self.0)
    }
}

/// What to fit, for whom, and by when.
pub struct JobSpec {
    pub data: EncryptedDataset,
    pub cfg: FitConfig,
    /// If set, run ELS-CD with this many coordinate updates instead of
    /// the GD family (used by the fig2 comparison workloads).
    pub cd_updates: Option<usize>,
    /// Owning tenant (cache partition + fairness lane). Defaults to
    /// the `"default"` tenant.
    pub tenant: TenantId,
    /// Completion deadline, milliseconds from submission. `None` means
    /// best-effort. A job whose deadline passes while still queued is
    /// expired *before* any engine work starts.
    pub deadline_ms: Option<u64>,
    /// Idempotent submission token. Two submits carrying the same
    /// `(tenant, token)` map to the *same* job: a client retrying after
    /// a lost reply re-attaches instead of paying for a second
    /// encrypted fit. `None` opts out (every submit is a new job).
    pub token: Option<String>,
}

impl JobSpec {
    pub fn new(data: EncryptedDataset, cfg: FitConfig, cd_updates: Option<usize>) -> Self {
        JobSpec {
            data,
            cfg,
            cd_updates,
            tenant: TenantId::default(),
            deadline_ms: None,
            token: None,
        }
    }

    pub fn with_tenant(mut self, tenant: TenantId) -> Self {
        self.tenant = tenant;
        self
    }

    pub fn with_deadline_ms(mut self, deadline_ms: u64) -> Self {
        self.deadline_ms = Some(deadline_ms);
        self
    }

    pub fn with_token(mut self, token: impl Into<String>) -> Self {
        self.token = Some(token.into());
        self
    }
}

/// Lifecycle.
pub enum JobState {
    Queued,
    Running,
    Done(EncryptedFit),
    Failed(String),
    /// Deadline passed before the job reached an execution lane.
    Expired,
    /// Bounced by a server drain while still queued: no engine work
    /// was performed; resubmit to another server.
    Cancelled,
}

impl JobState {
    pub fn label(&self) -> &'static str {
        match self {
            JobState::Queued => "queued",
            JobState::Running => "running",
            JobState::Done(_) => "done",
            JobState::Failed(_) => "failed",
            JobState::Expired => "expired",
            JobState::Cancelled => "cancelled",
        }
    }

    /// Terminal states fire the job's completion event exactly once.
    pub fn is_terminal(&self) -> bool {
        matches!(
            self,
            JobState::Done(_) | JobState::Failed(_) | JobState::Expired | JobState::Cancelled
        )
    }
}

/// A tracked job.
pub struct Job {
    pub id: JobId,
    pub tenant: TenantId,
    pub state: JobState,
    pub submitted: Instant,
    pub deadline: Option<Instant>,
    pub finished: Option<Instant>,
    /// One-shot completion event: waiters block here (one condvar per
    /// job), so a completion wakes this job's waiters and nobody else.
    pub done: Arc<Event>,
}

impl Job {
    pub fn new(id: JobId, tenant: TenantId, deadline: Option<Instant>) -> Self {
        Job {
            id,
            tenant,
            state: JobState::Queued,
            submitted: Instant::now(),
            deadline,
            finished: None,
            done: Arc::new(Event::new()),
        }
    }

    pub fn latency(&self) -> Option<Duration> {
        self.finished.map(|f| f - self.submitted)
    }
}
