//! Durable serving: an append-only write-ahead journal of job
//! lifecycle transitions.
//!
//! The paper's premise is that encrypted fits are *expensive* —
//! hundreds of ciphertext multiplies under §4.5 parameter bounds — so
//! the serving tier must survive its own process dying without losing
//! accepted work or recomputing finished iterations. Every lifecycle
//! transition (`accepted`/`started`/`checkpoint`/`done`/`acked`/
//! `failed`) is appended to `journal.wal` under `journal_dir` *before*
//! the transition is acted on, and `Coordinator::recover` folds the
//! log back into live state on startup.
//!
//! # Record format
//!
//! ```text
//! ┌──────────────┬────────────────┬──────────────────────┐
//! │ len: u32 LE  │ checksum: u64  │ payload: len bytes    │
//! │ (of payload) │ LE, FNV-1a 64  │ (one JSON document)   │
//! └──────────────┴────────────────┴──────────────────────┘
//! ```
//!
//! Payloads are the same line-protocol JSON the wire speaks (reusing
//! `protocol.rs` codecs for ciphertexts, fits and configs), framed
//! binary so a torn tail is *detectable*: on open the file is scanned
//! record-by-record and the first incomplete or checksum-failing
//! record — the classic torn write of a crash mid-append — truncates
//! the file back to the last good boundary. A torn tail is counted and
//! logged, never a recovery failure.
//!
//! # Fsync discipline
//!
//! Every append is followed by `fsync` before the caller proceeds, so
//! an `accepted` reply implies the job survives a crash, and a `done`
//! record implies the result is re-servable with zero engine work.
//! Failed appends repair the tail in-process (truncate back to the
//! last good boundary) and surface a retryable error — the journal
//! never silently continues past a record later readers would discard.

use std::collections::BTreeMap;
use std::fs::File;
use std::io::{Read as _, Seek as _, SeekFrom, Write as _};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use crate::coordinator::job::{JobId, JobSpec};
use crate::coordinator::protocol::{
    cfg_from_json, cfg_to_json, checkpoint_from_json, checkpoint_to_json, dataset_from_json,
    dataset_to_json, fit_from_json, fit_to_json, record_checksum, ErrorCode,
};
use crate::coordinator::tenant::TenantId;
use crate::els::encrypted::{DescentCheckpoint, EncryptedFit, FitConfig};
use crate::els::model::EncryptedDataset;
use crate::fhe::FvContext;
use crate::util::error::{bail, Context, Result};
use crate::util::faults::{self, FaultKind, FaultSite};
use crate::util::json::Json;

/// Journal schema version carried in every record payload.
pub const JOURNAL_VERSION: u64 = 1;

/// Frame header: payload length (u32 LE) + FNV-1a 64 checksum (u64 LE).
const HEADER_LEN: usize = 12;

/// Records longer than this are treated as corruption, not as a real
/// length — a torn length word must not make the scanner "wait" for
/// gigabytes that never existed.
const MAX_RECORD_LEN: usize = 1 << 30;

// ---- global counters (telemetry `journal` section) ----------------------

static RECORDS_WRITTEN: AtomicU64 = AtomicU64::new(0);
static RECORDS_REPLAYED: AtomicU64 = AtomicU64::new(0);
static RECORDS_TRUNCATED: AtomicU64 = AtomicU64::new(0);
static CHECKPOINTS_TAKEN: AtomicU64 = AtomicU64::new(0);
static CHECKPOINTS_RESUMED: AtomicU64 = AtomicU64::new(0);
static APPEND_ERRORS: AtomicU64 = AtomicU64::new(0);

/// Records appended (and fsynced) since process start.
pub fn records_written() -> u64 {
    RECORDS_WRITTEN.load(Ordering::Relaxed)
}

/// Records replayed by `Journal::open` since process start.
pub fn records_replayed() -> u64 {
    RECORDS_REPLAYED.load(Ordering::Relaxed)
}

/// Torn-tail truncation events (open-time and post-append repair).
pub fn records_truncated() -> u64 {
    RECORDS_TRUNCATED.load(Ordering::Relaxed)
}

/// Mid-fit descent checkpoints journaled since process start.
pub fn checkpoints_taken() -> u64 {
    CHECKPOINTS_TAKEN.load(Ordering::Relaxed)
}

/// Fits resumed from a journaled checkpoint since process start.
pub fn checkpoints_resumed() -> u64 {
    CHECKPOINTS_RESUMED.load(Ordering::Relaxed)
}

/// Appends that failed (io error or injected fault) since start.
pub fn append_errors() -> u64 {
    APPEND_ERRORS.load(Ordering::Relaxed)
}

/// Count one journaled mid-fit checkpoint (scheduler hook).
pub fn note_checkpoint_taken() {
    CHECKPOINTS_TAKEN.fetch_add(1, Ordering::Relaxed);
}

/// Count one checkpoint-resumed fit (scheduler recovery).
pub fn note_checkpoint_resumed() {
    CHECKPOINTS_RESUMED.fetch_add(1, Ordering::Relaxed);
}

// ---- byte-level scan (pure; the property-test surface) ------------------

/// Scan raw journal bytes into payload documents. Returns the decoded
/// payloads, the length of the clean prefix (the byte offset the next
/// append belongs at), and whether a torn/corrupt tail was found after
/// that prefix. Pure — property tests replay arbitrary prefixes
/// without touching the filesystem.
pub fn scan_bytes(bytes: &[u8]) -> (Vec<Json>, usize, bool) {
    let mut docs = Vec::new();
    let mut at = 0usize;
    while at < bytes.len() {
        let rest = &bytes[at..];
        if rest.len() < HEADER_LEN {
            return (docs, at, true);
        }
        let len = u32::from_le_bytes(rest[0..4].try_into().unwrap()) as usize;
        let sum = u64::from_le_bytes(rest[4..12].try_into().unwrap());
        if len > MAX_RECORD_LEN || rest.len() < HEADER_LEN + len {
            return (docs, at, true);
        }
        let payload = &rest[HEADER_LEN..HEADER_LEN + len];
        if record_checksum(payload) != sum {
            return (docs, at, true);
        }
        let text = match std::str::from_utf8(payload) {
            Ok(t) => t,
            Err(_) => return (docs, at, true),
        };
        let doc = match Json::parse(text) {
            Ok(d) => d,
            Err(_) => return (docs, at, true),
        };
        docs.push(doc);
        at += HEADER_LEN + len;
    }
    (docs, at, false)
}

/// Frame one payload document as journal bytes.
fn frame(payload: &Json) -> Vec<u8> {
    let body = payload.to_string_json().into_bytes();
    let mut out = Vec::with_capacity(HEADER_LEN + body.len());
    out.extend_from_slice(&(body.len() as u32).to_le_bytes());
    out.extend_from_slice(&record_checksum(&body).to_le_bytes());
    out.extend_from_slice(&body);
    out
}

// ---- the journal itself -------------------------------------------------

struct Wal {
    /// `None` once poisoned: crash simulation (and unrecoverable repair
    /// failures) stop all writes, as if the process had died.
    file: Option<File>,
    /// Byte offset of the last good record boundary.
    end: u64,
}

/// An open append-only write-ahead journal (`journal.wal` under the
/// directory given to [`Journal::open`]).
pub struct Journal {
    path: PathBuf,
    inner: Mutex<Wal>,
}

impl Journal {
    /// Open (or create) the journal under `dir`, replaying existing
    /// records. A torn or corrupt tail is truncated back to the last
    /// good record boundary — counted and reported, never an error.
    pub fn open(dir: impl AsRef<Path>) -> Result<(Journal, Vec<Json>)> {
        let dir = dir.as_ref();
        std::fs::create_dir_all(dir)
            .with_context(|| format!("creating journal dir {}", dir.display()))?;
        let path = dir.join("journal.wal");
        let mut file = File::options()
            .read(true)
            .write(true)
            .create(true)
            .truncate(false)
            .open(&path)
            .with_context(|| format!("opening journal {}", path.display()))?;
        let mut bytes = Vec::new();
        file.read_to_end(&mut bytes).context("reading journal")?;
        let (docs, good_end, torn) = scan_bytes(&bytes);
        if torn {
            file.set_len(good_end as u64).context("truncating torn journal tail")?;
            file.sync_data().context("syncing truncated journal")?;
            RECORDS_TRUNCATED.fetch_add(1, Ordering::Relaxed);
        }
        file.seek(SeekFrom::Start(good_end as u64)).context("seeking journal end")?;
        RECORDS_REPLAYED.fetch_add(docs.len() as u64, Ordering::Relaxed);
        let journal =
            Journal { path, inner: Mutex::new(Wal { file: Some(file), end: good_end as u64 }) };
        Ok((journal, docs))
    }

    /// The on-disk path of the journal file.
    pub fn path(&self) -> &Path {
        &self.path
    }

    /// Append one record and fsync it. On failure (real io error or an
    /// injected `journal` fault) the tail is repaired back to the last
    /// good boundary and the error surfaces to the caller — an
    /// unjournaled transition must never be acted on.
    pub fn append(&self, record: &JournalRecord) -> Result<()> {
        self.append_json(&record.to_json())
    }

    /// Append one pre-built payload document — the borrowed-payload
    /// twin of [`append`](Self::append). The scheduler journals
    /// `accepted` and `done` through [`accepted_payload`] /
    /// [`done_payload`] without cloning the dataset or fit into an
    /// owning [`JournalRecord`].
    pub(crate) fn append_json(&self, payload: &Json) -> Result<()> {
        let mut inner = self.inner.lock().unwrap_or_else(|e| e.into_inner());
        let end = inner.end;
        let Some(file) = inner.file.as_mut() else {
            bail!("journal closed (crashed or unrepairable)");
        };
        let bytes = frame(payload);
        match faults::check(FaultSite::Journal) {
            Some(FaultKind::IoError) => {
                APPEND_ERRORS.fetch_add(1, Ordering::Relaxed);
                bail!("injected journal io error");
            }
            Some(FaultKind::TornWrite) => {
                // Persist only a prefix — the torn write of a crash —
                // then repair the tail in-process so later appends (and
                // later readers) never sit behind a record the scanner
                // would discard.
                let cut = (bytes.len() / 2).max(1);
                let _ = file.write_all(&bytes[..cut]);
                let _ = file.flush();
                APPEND_ERRORS.fetch_add(1, Ordering::Relaxed);
                Self::repair(&mut inner, end);
                bail!("injected torn journal write (tail repaired)");
            }
            _ => {}
        }
        if let Err(e) = file.write_all(&bytes).and_then(|()| file.sync_data()) {
            APPEND_ERRORS.fetch_add(1, Ordering::Relaxed);
            Self::repair(&mut inner, end);
            bail!("journal append failed: {e}");
        }
        inner.end = end + bytes.len() as u64;
        RECORDS_WRITTEN.fetch_add(1, Ordering::Relaxed);
        Ok(())
    }

    /// Truncate back to the last good boundary; poison on failure.
    fn repair(inner: &mut Wal, end: u64) {
        let ok = inner.file.as_mut().is_some_and(|f| {
            f.set_len(end).and_then(|()| f.seek(SeekFrom::Start(end))).is_ok()
        });
        if !ok {
            // Cannot guarantee a clean tail: stop writing entirely.
            inner.file = None;
        }
        RECORDS_TRUNCATED.fetch_add(1, Ordering::Relaxed);
    }

    /// Fsync the journal (the final sync of a graceful drain).
    pub fn sync(&self) -> Result<()> {
        let mut inner = self.inner.lock().unwrap_or_else(|e| e.into_inner());
        if let Some(file) = inner.file.as_mut() {
            file.sync_data().context("syncing journal")?;
        }
        Ok(())
    }

    /// Crash simulation: suppress every further write, as if the
    /// process had died. The file on disk keeps exactly what was
    /// already fsynced.
    pub fn poison(&self) {
        let mut inner = self.inner.lock().unwrap_or_else(|e| e.into_inner());
        inner.file = None;
    }

    /// Crash simulation, torn-write flavour: persist a deliberately
    /// partial record (a header promising more bytes than follow) and
    /// then poison the journal — the on-disk state a crash mid-append
    /// leaves behind. Recovery must truncate it away.
    pub fn tear_tail(&self) {
        let mut inner = self.inner.lock().unwrap_or_else(|e| e.into_inner());
        if let Some(file) = inner.file.as_mut() {
            let torn = frame(&Json::obj(vec![
                ("v", Json::Num(JOURNAL_VERSION as f64)),
                ("event", Json::str("torn")),
            ]));
            let cut = torn.len() - torn.len() / 3 - 1;
            let _ = file.write_all(&torn[..cut]);
            let _ = file.sync_data();
        }
        inner.file = None;
    }
}

// ---- lifecycle records --------------------------------------------------

/// One journaled job lifecycle transition. `Accepted` carries the full
/// re-enqueue payload (dataset, config, tenancy, token); the others
/// reference the job id it introduced.
pub enum JournalRecord {
    /// The job was admitted: everything needed to re-run it.
    Accepted {
        id: JobId,
        tenant: TenantId,
        token: Option<String>,
        deadline_ms: Option<u64>,
        cfg: FitConfig,
        cd_updates: Option<usize>,
        data: EncryptedDataset,
    },
    /// An execution lane picked the job up.
    Started { id: JobId },
    /// Mid-fit descent resume point (every k iterations).
    Checkpoint { id: JobId, ckpt: DescentCheckpoint },
    /// The fit finished; the result is re-servable from the journal.
    Done { id: JobId, fit: EncryptedFit },
    /// The client acknowledged delivery; the job can be forgotten.
    Acked { id: JobId },
    /// Terminal failure (panic, engine error, expiry, drain bounce).
    Failed { id: JobId, code: ErrorCode, message: String },
}

impl JournalRecord {
    /// The job this record belongs to.
    pub fn id(&self) -> JobId {
        match self {
            JournalRecord::Accepted { id, .. }
            | JournalRecord::Started { id }
            | JournalRecord::Checkpoint { id, .. }
            | JournalRecord::Done { id, .. }
            | JournalRecord::Acked { id }
            | JournalRecord::Failed { id, .. } => *id,
        }
    }

    /// The payload `event` tag.
    pub fn event(&self) -> &'static str {
        match self {
            JournalRecord::Accepted { .. } => "accepted",
            JournalRecord::Started { .. } => "started",
            JournalRecord::Checkpoint { .. } => "checkpoint",
            JournalRecord::Done { .. } => "done",
            JournalRecord::Acked { .. } => "acked",
            JournalRecord::Failed { .. } => "failed",
        }
    }

    pub fn to_json(&self) -> Json {
        match self {
            JournalRecord::Accepted { id, tenant, token, deadline_ms, cfg, cd_updates, data } => {
                accepted_parts(*id, tenant, token.as_deref(), *deadline_ms, cfg, *cd_updates, data)
            }
            JournalRecord::Done { id, fit } => done_payload(*id, fit),
            other => {
                let mut fields = vec![
                    ("v", Json::Num(JOURNAL_VERSION as f64)),
                    ("event", Json::str(other.event())),
                    ("id", Json::Num(other.id().0 as f64)),
                ];
                match other {
                    JournalRecord::Checkpoint { ckpt, .. } => {
                        fields.push(("ckpt", checkpoint_to_json(ckpt)));
                    }
                    JournalRecord::Failed { code, message, .. } => {
                        fields.push(("code", Json::str(code.as_str())));
                        fields.push(("error", Json::str(message)));
                    }
                    _ => {}
                }
                Json::obj(fields)
            }
        }
    }

    pub fn from_json(ctx: &FvContext, j: &Json) -> Result<JournalRecord> {
        let v = j.req("v")?.as_u64().context("journal record version")?;
        if v != JOURNAL_VERSION {
            bail!("unsupported journal record version {v}");
        }
        let id = JobId(j.req("id")?.as_u64().context("journal record id")?);
        Ok(match j.req("event")?.as_str().context("journal record event")? {
            "accepted" => {
                let (cfg, cd_updates) = cfg_from_json(j.req("cfg")?)?;
                JournalRecord::Accepted {
                    id,
                    tenant: TenantId::new(
                        j.get("tenant").and_then(|t| t.as_str()).unwrap_or("default"),
                    ),
                    token: j.get("token").and_then(|t| t.as_str()).map(String::from),
                    deadline_ms: j.get("deadline_ms").and_then(|d| d.as_u64()),
                    cfg,
                    cd_updates,
                    data: dataset_from_json(ctx, j.req("data")?)?,
                }
            }
            "started" => JournalRecord::Started { id },
            "checkpoint" => {
                JournalRecord::Checkpoint { id, ckpt: checkpoint_from_json(ctx, j.req("ckpt")?)? }
            }
            "done" => JournalRecord::Done { id, fit: fit_from_json(ctx, j.req("fit")?)? },
            "acked" => JournalRecord::Acked { id },
            "failed" => JournalRecord::Failed {
                id,
                code: ErrorCode::from_str(j.req("code")?.as_str().context("code")?)
                    .context("unknown journal error code")?,
                message: j.get("error").and_then(|e| e.as_str()).unwrap_or("").to_string(),
            },
            other => bail!("unknown journal event '{other}'"),
        })
    }
}

// ---- borrowed payload builders (scheduler fast path) --------------------

/// The `accepted` payload for a spec the scheduler still owns — same
/// document [`JournalRecord::Accepted`] serialises to, built without
/// cloning the encrypted dataset into an owning record.
pub(crate) fn accepted_payload(id: JobId, spec: &JobSpec) -> Json {
    accepted_parts(
        id,
        &spec.tenant,
        spec.token.as_deref(),
        spec.deadline_ms,
        &spec.cfg,
        spec.cd_updates,
        &spec.data,
    )
}

fn accepted_parts(
    id: JobId,
    tenant: &TenantId,
    token: Option<&str>,
    deadline_ms: Option<u64>,
    cfg: &FitConfig,
    cd_updates: Option<usize>,
    data: &EncryptedDataset,
) -> Json {
    let mut fields = vec![
        ("v", Json::Num(JOURNAL_VERSION as f64)),
        ("event", Json::str("accepted")),
        ("id", Json::Num(id.0 as f64)),
        ("tenant", Json::str(&tenant.0)),
    ];
    if let Some(t) = token {
        fields.push(("token", Json::str(t)));
    }
    if let Some(d) = deadline_ms {
        fields.push(("deadline_ms", Json::Num(d as f64)));
    }
    fields.push(("cfg", cfg_to_json(cfg, cd_updates)));
    fields.push(("data", dataset_to_json(data)));
    Json::obj(fields)
}

/// The `done` payload for a fit the scheduler still owns.
pub(crate) fn done_payload(id: JobId, fit: &EncryptedFit) -> Json {
    Json::obj(vec![
        ("v", Json::Num(JOURNAL_VERSION as f64)),
        ("event", Json::str("done")),
        ("id", Json::Num(id.0 as f64)),
        ("fit", fit_to_json(fit)),
    ])
}

// ---- replay fold --------------------------------------------------------

/// The folded recovery state of one journaled job.
pub struct ReplayJob {
    pub tenant: TenantId,
    pub token: Option<String>,
    pub deadline_ms: Option<u64>,
    pub cfg: FitConfig,
    pub cd_updates: Option<usize>,
    pub data: EncryptedDataset,
    pub started: bool,
    pub ckpt: Option<DescentCheckpoint>,
    pub fit: Option<EncryptedFit>,
    pub failed: Option<(ErrorCode, String)>,
    pub acked: bool,
}

/// Journal replay result: per-job folded state plus the id watermark.
pub struct ReplayState {
    /// Keyed by raw job id, in id order.
    pub jobs: BTreeMap<u64, ReplayJob>,
    /// Highest job id seen (0 when the journal is empty).
    pub max_id: u64,
}

/// Fold a record sequence into per-job recovery state. Records for ids
/// with no surviving `accepted` (possible when an earlier truncation
/// repair dropped one) are skipped — replay of any journal prefix must
/// always succeed.
pub fn replay(records: Vec<JournalRecord>) -> ReplayState {
    let mut jobs: BTreeMap<u64, ReplayJob> = BTreeMap::new();
    let mut max_id = 0u64;
    for rec in records {
        max_id = max_id.max(rec.id().0);
        match rec {
            JournalRecord::Accepted { id, tenant, token, deadline_ms, cfg, cd_updates, data } => {
                jobs.insert(
                    id.0,
                    ReplayJob {
                        tenant,
                        token,
                        deadline_ms,
                        cfg,
                        cd_updates,
                        data,
                        started: false,
                        ckpt: None,
                        fit: None,
                        failed: None,
                        acked: false,
                    },
                );
            }
            JournalRecord::Started { id } => {
                if let Some(job) = jobs.get_mut(&id.0) {
                    job.started = true;
                }
            }
            JournalRecord::Checkpoint { id, ckpt } => {
                if let Some(job) = jobs.get_mut(&id.0) {
                    job.ckpt = Some(ckpt);
                }
            }
            JournalRecord::Done { id, fit } => {
                if let Some(job) = jobs.get_mut(&id.0) {
                    job.fit = Some(fit);
                }
            }
            JournalRecord::Acked { id } => {
                if let Some(job) = jobs.get_mut(&id.0) {
                    job.acked = true;
                }
            }
            JournalRecord::Failed { id, code, message } => {
                if let Some(job) = jobs.get_mut(&id.0) {
                    job.failed = Some((code, message));
                }
            }
        }
    }
    ReplayState { jobs, max_id }
}

#[cfg(test)]
mod tests {
    use std::sync::Arc;

    use super::*;
    use crate::els::exact::QuantisedData;
    use crate::els::model::encrypt_dataset;
    use crate::fhe::keys::keygen;
    use crate::fhe::params::FvParams;
    use crate::fhe::rng::ChaChaRng;
    use crate::util::prop::PropRunner;

    struct World {
        ctx: Arc<FvContext>,
        data: EncryptedDataset,
    }

    fn world(seed: u64) -> World {
        let ctx = FvContext::new(FvParams::custom(256, 2, 16));
        let mut rng = ChaChaRng::from_seed(seed);
        let keys = keygen(&ctx, &mut rng);
        let q = QuantisedData { x: vec![vec![3, -1], vec![2, 5]], y: vec![7, -4], phi: 1 };
        let data = encrypt_dataset(&ctx, &keys.pk, &q, &mut rng);
        World { ctx, data }
    }

    fn accepted(w: &World, id: u64) -> JournalRecord {
        JournalRecord::Accepted {
            id: JobId(id),
            tenant: TenantId::new("acme"),
            token: Some(format!("tok-{id}")),
            deadline_ms: Some(5000),
            cfg: FitConfig::gd(2, 9),
            cd_updates: None,
            data: w.data.clone(),
        }
    }

    fn tmpdir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!(
            "els-journal-{tag}-{}-{:?}",
            std::process::id(),
            std::thread::current().id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        dir
    }

    #[test]
    fn records_roundtrip_through_frames_and_json() {
        let w = world(901);
        let dir = tmpdir("roundtrip");
        let (journal, replayed) = Journal::open(&dir).unwrap();
        assert!(replayed.is_empty());
        journal.append(&accepted(&w, 1)).unwrap();
        journal.append(&JournalRecord::Started { id: JobId(1) }).unwrap();
        journal
            .append(&JournalRecord::Failed {
                id: JobId(1),
                code: ErrorCode::JobFailed,
                message: "lane panic".into(),
            })
            .unwrap();
        journal.append(&JournalRecord::Acked { id: JobId(1) }).unwrap();
        drop(journal);
        let (_, docs) = Journal::open(&dir).unwrap();
        assert_eq!(docs.len(), 4);
        let recs: Vec<JournalRecord> =
            docs.iter().map(|d| JournalRecord::from_json(&w.ctx, d).unwrap()).collect();
        assert_eq!(recs[0].event(), "accepted");
        let state = replay(recs);
        assert_eq!(state.max_id, 1);
        let job = &state.jobs[&1];
        assert_eq!(job.tenant.0, "acme");
        assert_eq!(job.token.as_deref(), Some("tok-1"));
        assert!(job.started && job.acked);
        assert_eq!(job.failed.as_ref().unwrap().0, ErrorCode::JobFailed);
        assert_eq!(job.data.n(), 2);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn torn_tail_truncates_and_appends_continue() {
        let w = world(902);
        let dir = tmpdir("torn");
        let (journal, _) = Journal::open(&dir).unwrap();
        journal.append(&accepted(&w, 1)).unwrap();
        journal.append(&JournalRecord::Started { id: JobId(1) }).unwrap();
        // Crash mid-append: a partial record lands on disk.
        journal.tear_tail();
        assert!(
            journal.append(&JournalRecord::Acked { id: JobId(1) }).is_err(),
            "poisoned journal must reject writes"
        );
        let truncations = records_truncated();
        let (journal2, docs) = Journal::open(&dir).unwrap();
        assert_eq!(docs.len(), 2, "torn tail must not cost good records");
        assert_eq!(records_truncated(), truncations + 1, "truncation is counted");
        // The repaired journal accepts appends at the clean boundary.
        journal2.append(&JournalRecord::Acked { id: JobId(1) }).unwrap();
        drop(journal2);
        let (_, docs) = Journal::open(&dir).unwrap();
        assert_eq!(docs.len(), 3);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn corrupt_checksum_truncates_from_corruption_point() {
        let w = world(903);
        let dir = tmpdir("corrupt");
        let (journal, _) = Journal::open(&dir).unwrap();
        journal.append(&accepted(&w, 1)).unwrap();
        let boundary = std::fs::metadata(journal.path()).unwrap().len();
        journal.append(&JournalRecord::Started { id: JobId(1) }).unwrap();
        let path = journal.path().to_path_buf();
        drop(journal);
        // Flip one payload byte of the second record.
        let mut bytes = std::fs::read(&path).unwrap();
        let at = boundary as usize + HEADER_LEN;
        bytes[at] ^= 0x40;
        std::fs::write(&path, &bytes).unwrap();
        let (_, docs) = Journal::open(&dir).unwrap();
        assert_eq!(docs.len(), 1, "corruption truncates from the corrupt record");
        assert_eq!(std::fs::metadata(&path).unwrap().len(), boundary);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn injected_faults_fail_append_and_repair_tail() {
        use crate::util::faults::{FaultSession, FaultSpec};
        let w = world(904);
        let dir = tmpdir("faults");
        let (journal, _) = Journal::open(&dir).unwrap();
        journal.append(&accepted(&w, 1)).unwrap();
        for kind in [FaultKind::IoError, FaultKind::TornWrite] {
            let _s = FaultSession::activate(&[FaultSpec {
                site: FaultSite::Journal,
                kind,
                rate: 1.0,
                seed: 11,
            }]);
            let errs = append_errors();
            assert!(journal.append(&JournalRecord::Started { id: JobId(1) }).is_err());
            assert_eq!(append_errors(), errs + 1);
        }
        // Disarmed: the repaired tail takes the append cleanly.
        journal.append(&JournalRecord::Started { id: JobId(1) }).unwrap();
        drop(journal);
        let (_, docs) = Journal::open(&dir).unwrap();
        assert_eq!(docs.len(), 2, "failed appends leave no partial records behind");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn replay_of_any_prefix_twice_is_idempotent() {
        // The satellite property: for ANY byte prefix of a valid
        // journal, scanning is total (good records before the cut
        // survive, the torn tail is flagged, never an error) and
        // folding the same prefix twice yields the same recovered
        // state.
        let w = world(905);
        let mut bytes = Vec::new();
        let mut boundaries = vec![0usize];
        for id in 1..=4u64 {
            for rec in [
                accepted(&w, id),
                JournalRecord::Started { id: JobId(id) },
                JournalRecord::Done { id: JobId(id), fit: dummy_fit(&w) },
                JournalRecord::Acked { id: JobId(id) },
            ] {
                bytes.extend_from_slice(&frame(&rec.to_json()));
                boundaries.push(bytes.len());
            }
        }
        let summarise = |prefix: &[u8]| -> (Vec<(u64, bool, bool, bool)>, usize, bool) {
            let (docs, good_end, torn) = scan_bytes(prefix);
            let recs: Vec<JournalRecord> =
                docs.iter().map(|d| JournalRecord::from_json(&w.ctx, d).unwrap()).collect();
            let state = replay(recs);
            let jobs = state
                .jobs
                .iter()
                .map(|(id, j)| (*id, j.started, j.fit.is_some(), j.acked))
                .collect();
            (jobs, good_end, torn)
        };
        let mut run = PropRunner::new("journal_prefix_replay", 200);
        run.run(|rng| {
            let cut = (rng.next_u64() as usize) % (bytes.len() + 1);
            let prefix = &bytes[..cut];
            let a = summarise(prefix);
            let b = summarise(prefix);
            assert_eq!(a, b, "replaying the same prefix twice diverged");
            let (jobs, good_end, torn) = a;
            // The clean prefix always ends on a true record boundary,
            // and a mid-record cut is flagged torn.
            assert!(boundaries.contains(&good_end), "good_end {good_end} off-boundary");
            assert_eq!(torn, !boundaries.contains(&cut));
            assert!(good_end <= cut);
            // Recovered jobs are exactly those whose `accepted` record
            // (the first of each job's four) fits in the clean prefix.
            let full_records = boundaries.iter().filter(|&&b| b > 0 && b <= good_end).count();
            assert_eq!(jobs.len(), full_records.div_ceil(4), "{jobs:?} vs {full_records} records");
        });
    }

    fn dummy_fit(w: &World) -> EncryptedFit {
        EncryptedFit {
            betas: vec![w.data.y[0].clone()],
            divisor: crate::math::bigint::BigUint::from_u64(100),
            path: None,
            phi: 1,
            paper_mmd: 4,
            noise_depth: 3,
        }
    }
}
