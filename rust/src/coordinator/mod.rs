//! The serving layer (Layer 3): accepts encrypted regression jobs over
//! a TCP JSON protocol, runs §4.5 admission control, schedules them on
//! worker threads, and coalesces their homomorphic multiplications into
//! fused backend batches (native threads or XLA artifact launches).
//!
//! - [`job`] — specs and lifecycle.
//! - [`admission`] — depth/growth guardrails with planner proposals.
//! - [`batcher`] — cross-job dynamic batching (`BatchingEngine`).
//! - [`arena`] — ciphertext slot slab with high-water accounting.
//! - [`scheduler`] — the `Coordinator` itself.
//! - [`metrics`] — counters and latency histograms.
//! - [`protocol`] / [`service`] — wire codec, TCP server and client.

pub mod admission;
pub mod arena;
pub mod batcher;
pub mod job;
pub mod metrics;
pub mod protocol;
pub mod scheduler;
pub mod service;

pub use batcher::{BatchConfig, BatchingEngine};
pub use job::{JobId, JobSpec};
pub use scheduler::Coordinator;
pub use service::{Client, Server};
