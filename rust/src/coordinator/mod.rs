//! The serving layer (Layer 3): accepts encrypted regression jobs over
//! a TCP JSON protocol, runs §4.5 admission control, schedules them on
//! worker threads, and coalesces their homomorphic multiplications into
//! fused backend batches (native threads or XLA artifact launches).
//!
//! - [`job`] — specs and lifecycle.
//! - [`admission`] — depth/growth guardrails with planner proposals,
//!   plus load/deadline admission under saturation.
//! - [`tenant`] — tenant registry, per-tenant operand caches, and the
//!   per-job `TenantEngine` view.
//! - [`batcher`] — cross-job dynamic batching (`BatchingEngine`).
//! - [`arena`] — ciphertext slot slab with high-water accounting and
//!   the byte-budgeted LRU behind the tenant caches.
//! - [`scheduler`] — the `Coordinator` itself (executor lanes, timer
//!   wheel, per-tenant fair queues).
//! - [`journal`] — append-only write-ahead journal of job lifecycle
//!   transitions; crash/restart recovery replays it.
//! - [`metrics`] — counters and latency histograms.
//! - [`protocol`] / [`service`] — versioned wire codec with structured
//!   error codes, TCP server and client.
//! - [`retry`] — retrying client: capped decorrelated-jitter backoff
//!   over the retryable error codes, idempotent resubmission.

pub mod admission;
pub mod arena;
pub mod batcher;
pub mod job;
pub mod journal;
pub mod metrics;
pub mod protocol;
pub mod retry;
pub mod scheduler;
pub mod service;
pub mod tenant;

pub use batcher::{BatchConfig, BatchingEngine};
pub use job::{JobId, JobSpec};
pub use journal::{Journal, JournalRecord};
pub use protocol::{ErrorCode, WireError, WireResult, PROTOCOL_VERSION};
pub use retry::{RetryPolicy, RetryingClient};
pub use scheduler::{Coordinator, CoordinatorConfig, DrainReport, RecoveredCounts};
pub use service::{Client, Server};
pub use tenant::{TenantEngine, TenantId, TenantRegistry};
