//! Multi-tenancy: tenant identities, per-tenant counters, and sharded
//! byte-budgeted `PlaintextNtt` operand caches.
//!
//! All tenants share one FV context and evaluation keyset — that is
//! what makes cross-job coalescing bit-identical — but each tenant gets
//! its own operand cache (so one tenant's working set cannot evict
//! another's hot constants) and its own submission counters (so the
//! fairness and admission decisions have per-tenant signals).
//! [`TenantEngine`] is the per-job engine wrapper: it forwards every
//! homomorphic op to the shared engine and intercepts only
//! `prepare_plaintext`, serving repeated descent constants (step sizes,
//! carry constants, `c_y` scalings) from the tenant's cache instead of
//! re-running the forward NTT per job.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::fhe::{Ciphertext, FvContext, Plaintext, PlaintextNtt};
use crate::runtime::backend::{HeEngine, OpStats};
use crate::util::error::Result;
use crate::util::faults::{self, FaultSite};
use crate::util::json::Json;
use crate::util::lru::LruBytes;

/// Tenant identity: an opaque caller-chosen string. Jobs submitted
/// without one land in the `"default"` tenant.
#[derive(Clone, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct TenantId(pub String);

impl TenantId {
    pub fn new(id: impl Into<String>) -> Self {
        TenantId(id.into())
    }
}

impl Default for TenantId {
    fn default() -> Self {
        TenantId("default".to_string())
    }
}

impl std::fmt::Display for TenantId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.0)
    }
}

/// Per-tenant submission counters.
#[derive(Default)]
pub struct TenantCounters {
    pub jobs_submitted: AtomicU64,
    pub jobs_completed: AtomicU64,
    pub jobs_rejected: AtomicU64,
}

/// Exact canonical cache key for a plaintext operand: per coefficient,
/// the sign flag, the limb count, then the magnitude limbs. Exactness
/// matters — a hashed key colliding would silently multiply a job by
/// the *wrong* cached operand; a representation mismatch here merely
/// costs a cache miss.
fn operand_key(pt: &Plaintext) -> Vec<u64> {
    let mut key = Vec::with_capacity(pt.coeffs.len() * 2 + 1);
    key.push(pt.coeffs.len() as u64);
    for c in &pt.coeffs {
        let limbs = c.mag.limbs();
        key.push(((limbs.len() as u64) << 1) | u64::from(c.neg));
        key.extend_from_slice(limbs);
    }
    key
}

fn operand_bytes(m: &PlaintextNtt) -> usize {
    m.m_ntt.planes.len() * m.m_ntt.d * 8 + 64
}

/// Sharded byte-budgeted operand cache. Shards split both the lock and
/// the budget, so concurrent jobs of one tenant don't serialise on a
/// single cache mutex.
pub struct OperandCache {
    shards: Vec<Mutex<LruBytes<Vec<u64>, PlaintextNtt>>>,
}

impl OperandCache {
    pub fn new(budget_bytes: usize, shards: usize) -> Self {
        let shards = shards.max(1);
        let per_shard = (budget_bytes / shards).max(1);
        OperandCache {
            shards: (0..shards).map(|_| Mutex::new(LruBytes::new(per_shard))).collect(),
        }
    }

    fn shard_of(&self, key: &[u64]) -> usize {
        // Cheap deterministic mix; the key itself stays exact.
        let mut h = 0xcbf2_9ce4_8422_2325u64;
        for &w in key {
            h = (h ^ w).wrapping_mul(0x1000_0000_01b3);
        }
        (h % self.shards.len() as u64) as usize
    }

    /// Fetch the prepared operand for `pt`, preparing and caching it on
    /// a miss via `prepare`.
    pub fn get_or_prepare(
        &self,
        pt: &Plaintext,
        prepare: impl FnOnce() -> PlaintextNtt,
    ) -> PlaintextNtt {
        let key = operand_key(pt);
        let shard = &self.shards[self.shard_of(&key)];
        // Chaos `cache:evict`: flush the shard before the lookup. Fits
        // must stay bit-identical with a cold cache — residency is a
        // performance property, never a correctness one.
        if faults::check(FaultSite::Cache).is_some() {
            let _ = shard.lock().unwrap().evict_all();
        }
        if let Some(hit) = shard.lock().unwrap().get(&key) {
            return hit.clone();
        }
        // Prepare outside the shard lock: the forward NTT is the
        // expensive part and must not serialise other lookups.
        let prepared = prepare();
        let bytes = operand_bytes(&prepared);
        shard.lock().unwrap().insert(key, prepared.clone(), bytes);
        prepared
    }

    /// Aggregate `(hits, misses, evictions)` across shards.
    pub fn stats(&self) -> (u64, u64, u64) {
        let mut agg = (0, 0, 0);
        for s in &self.shards {
            let (h, m, e) = s.lock().unwrap().stats();
            agg = (agg.0 + h, agg.1 + m, agg.2 + e);
        }
        agg
    }

    pub fn live_bytes(&self) -> usize {
        self.shards.iter().map(|s| s.lock().unwrap().live_bytes()).sum()
    }

    pub fn entries(&self) -> usize {
        self.shards.iter().map(|s| s.lock().unwrap().len()).sum()
    }

    /// Forced eviction across every shard (operator hook; also what
    /// the chaos `cache:evict` fault drives per-shard). Returns the
    /// number of entries dropped.
    pub fn evict_all(&self) -> usize {
        self.shards.iter().map(|s| s.lock().unwrap().evict_all()).sum()
    }
}

/// Everything the coordinator tracks per tenant.
pub struct TenantState {
    pub id: TenantId,
    pub cache: OperandCache,
    pub counters: TenantCounters,
}

impl TenantState {
    pub fn to_json(&self) -> Json {
        let (hits, misses, evictions) = self.cache.stats();
        Json::obj(vec![
            ("tenant", Json::str(&self.id.0)),
            (
                "jobs_submitted",
                Json::Num(self.counters.jobs_submitted.load(Ordering::Relaxed) as f64),
            ),
            (
                "jobs_completed",
                Json::Num(self.counters.jobs_completed.load(Ordering::Relaxed) as f64),
            ),
            (
                "jobs_rejected",
                Json::Num(self.counters.jobs_rejected.load(Ordering::Relaxed) as f64),
            ),
            ("cache_hits", Json::Num(hits as f64)),
            ("cache_misses", Json::Num(misses as f64)),
            ("cache_evictions", Json::Num(evictions as f64)),
            ("cache_bytes", Json::Num(self.cache.live_bytes() as f64)),
            ("cache_entries", Json::Num(self.cache.entries() as f64)),
        ])
    }
}

/// Registry of tenants, created lazily on first submission.
pub struct TenantRegistry {
    tenants: Mutex<BTreeMap<TenantId, Arc<TenantState>>>,
    cache_budget_bytes: usize,
    cache_shards: usize,
}

impl TenantRegistry {
    pub fn new(cache_budget_bytes: usize, cache_shards: usize) -> Self {
        TenantRegistry {
            tenants: Mutex::new(BTreeMap::new()),
            cache_budget_bytes,
            cache_shards,
        }
    }

    pub fn get_or_create(&self, id: &TenantId) -> Arc<TenantState> {
        let mut map = self.tenants.lock().unwrap();
        Arc::clone(map.entry(id.clone()).or_insert_with(|| {
            Arc::new(TenantState {
                id: id.clone(),
                cache: OperandCache::new(self.cache_budget_bytes, self.cache_shards),
                counters: TenantCounters::default(),
            })
        }))
    }

    pub fn get(&self, id: &TenantId) -> Option<Arc<TenantState>> {
        self.tenants.lock().unwrap().get(id).cloned()
    }

    pub fn len(&self) -> usize {
        self.tenants.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// One JSON object per tenant, sorted by tenant id.
    pub fn to_json(&self) -> Json {
        let map = self.tenants.lock().unwrap();
        Json::Arr(map.values().map(|t| t.to_json()).collect())
    }
}

/// The per-job engine view: shared context, keys and batching, but
/// `prepare_plaintext` served from the owning tenant's operand cache.
/// Every other op forwards verbatim — including the keyed
/// `rotate_rows`/`slot_sum` overrides of the shared engine, which a
/// default-method fallback would silently lose.
pub struct TenantEngine {
    inner: Arc<dyn HeEngine>,
    tenant: Arc<TenantState>,
}

impl TenantEngine {
    pub fn new(inner: Arc<dyn HeEngine>, tenant: Arc<TenantState>) -> Self {
        TenantEngine { inner, tenant }
    }

    pub fn tenant(&self) -> &TenantState {
        &self.tenant
    }
}

impl HeEngine for TenantEngine {
    fn ctx(&self) -> &FvContext {
        self.inner.ctx()
    }

    fn mul_pairs(&self, pairs: &[(&Ciphertext, &Ciphertext)]) -> Vec<Ciphertext> {
        self.inner.mul_pairs(pairs)
    }

    fn dot_pairs(&self, groups: &[&[(&Ciphertext, &Ciphertext)]]) -> Vec<Ciphertext> {
        self.inner.dot_pairs(groups)
    }

    fn stats(&self) -> &OpStats {
        self.inner.stats()
    }

    fn add(&self, a: &Ciphertext, b: &Ciphertext) -> Ciphertext {
        self.inner.add(a, b)
    }

    fn sub(&self, a: &Ciphertext, b: &Ciphertext) -> Ciphertext {
        self.inner.sub(a, b)
    }

    fn neg(&self, a: &Ciphertext) -> Ciphertext {
        self.inner.neg(a)
    }

    fn mul_plain(&self, a: &Ciphertext, pt: &Plaintext) -> Ciphertext {
        self.inner.mul_plain(a, pt)
    }

    fn prepare_plaintext(&self, pt: &Plaintext) -> PlaintextNtt {
        self.tenant.cache.get_or_prepare(pt, || self.inner.prepare_plaintext(pt))
    }

    fn mul_plain_prepared(&self, a: &Ciphertext, m: &PlaintextNtt) -> Ciphertext {
        self.inner.mul_plain_prepared(a, m)
    }

    fn rotate_rows(&self, ct: &Ciphertext, steps: usize) -> Result<Ciphertext> {
        self.inner.rotate_rows(ct, steps)
    }

    fn slot_sum(&self, ct: &Ciphertext) -> Result<Ciphertext> {
        self.inner.slot_sum(ct)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fhe::encoding::encode_int;
    use crate::fhe::params::FvParams;
    use crate::runtime::backend::NativeEngine;

    fn shared_engine() -> (Arc<FvContext>, Arc<dyn HeEngine>) {
        let ctx = FvContext::new(FvParams::custom(256, 2, 16));
        let mut rng = crate::fhe::rng::ChaChaRng::from_seed(901);
        let keys = crate::fhe::keys::keygen(&ctx, &mut rng);
        let engine: Arc<dyn HeEngine> =
            Arc::new(NativeEngine::new(ctx.clone(), Arc::new(keys.rk)));
        (ctx, engine)
    }

    #[test]
    fn tenant_cache_hits_on_repeated_operand() {
        let (ctx, engine) = shared_engine();
        let reg = TenantRegistry::new(1 << 20, 2);
        let tenant = reg.get_or_create(&TenantId::new("acme"));
        let te = TenantEngine::new(engine, Arc::clone(&tenant));
        let pt = encode_int(42, ctx.d());
        let a = te.prepare_plaintext(&pt);
        let b = te.prepare_plaintext(&pt);
        // Cache hit: the Arc'd NTT plane is literally shared.
        assert!(Arc::ptr_eq(&a.m_ntt, &b.m_ntt));
        let (hits, misses, _) = tenant.cache.stats();
        assert_eq!((hits, misses), (1, 1));
    }

    #[test]
    fn tenant_cache_evicts_under_byte_budget() {
        let (ctx, engine) = shared_engine();
        // Budget ≈ 2 operands (one operand = 2 planes × 256 × 8 = 4096
        // bytes + overhead), single shard so eviction order is exact.
        let reg = TenantRegistry::new(2 * 4200, 1);
        let tenant = reg.get_or_create(&TenantId::new("small"));
        let te = TenantEngine::new(engine, Arc::clone(&tenant));
        for v in 0..6 {
            let _ = te.prepare_plaintext(&encode_int(v, ctx.d()));
        }
        let (_, misses, evictions) = tenant.cache.stats();
        assert_eq!(misses, 6);
        assert!(evictions >= 4, "expected ≥4 evictions, saw {evictions}");
        assert!(tenant.cache.live_bytes() <= 2 * 4200);
        assert!(tenant.cache.entries() <= 2);
    }

    #[test]
    fn tenants_are_isolated() {
        let (ctx, engine) = shared_engine();
        let reg = TenantRegistry::new(1 << 20, 2);
        let a = reg.get_or_create(&TenantId::new("a"));
        let b = reg.get_or_create(&TenantId::new("b"));
        assert_eq!(reg.len(), 2);
        let ta = TenantEngine::new(Arc::clone(&engine), Arc::clone(&a));
        let tb = TenantEngine::new(engine, Arc::clone(&b));
        let pt = encode_int(7, ctx.d());
        let _ = ta.prepare_plaintext(&pt);
        let _ = tb.prepare_plaintext(&pt);
        // Same operand, but each tenant pays its own miss: caches are
        // not shared across the tenancy boundary.
        assert_eq!(a.cache.stats().1, 1);
        assert_eq!(b.cache.stats().1, 1);
        let json = reg.to_json().to_string_json();
        assert!(json.contains("\"tenant\":\"a\""), "{json}");
        assert!(json.contains("\"tenant\":\"b\""), "{json}");
    }

    #[test]
    fn tenant_engine_preserves_homomorphic_results() {
        // A multiply through the TenantEngine must be bit-identical to
        // the shared engine's own result (the wrapper adds caching, not
        // arithmetic).
        let ctx = FvContext::new(FvParams::custom(256, 2, 16));
        let mut rng = crate::fhe::rng::ChaChaRng::from_seed(902);
        let keys = crate::fhe::keys::keygen(&ctx, &mut rng);
        let engine: Arc<dyn HeEngine> =
            Arc::new(NativeEngine::new(ctx.clone(), Arc::new(keys.rk)));
        let reg = TenantRegistry::new(1 << 20, 2);
        let te = TenantEngine::new(Arc::clone(&engine), reg.get_or_create(&TenantId::default()));
        let a = ctx.encrypt(&encode_int(5, ctx.d()), &keys.pk, &mut rng);
        let b = ctx.encrypt(&encode_int(-3, ctx.d()), &keys.pk, &mut rng);
        let solo = engine.mul(&a, &b);
        let via_tenant = te.mul(&a, &b);
        assert_eq!(via_tenant.polys, solo.polys);
        let pt = encode_int(4, ctx.d());
        let prepared = te.prepare_plaintext(&pt);
        let solo_mp = engine.mul_plain_prepared(&a, &engine.prepare_plaintext(&pt));
        let via_mp = te.mul_plain_prepared(&a, &prepared);
        assert_eq!(via_mp.polys, solo_mp.polys);
    }
}
