//! Coordinator metrics: counters and a fixed-bucket latency histogram.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

use crate::util::json::Json;

/// Log-spaced latency buckets (upper bounds, ms). Observations above
/// the last bound land in a 13th overflow bucket.
const BUCKET_MS: [u64; 12] = [1, 2, 5, 10, 25, 50, 100, 250, 500, 1000, 5000, 30000];

/// Latency histogram (lock-free).
#[derive(Default)]
pub struct Histogram {
    buckets: [AtomicU64; 13],
    count: AtomicU64,
    sum_us: AtomicU64,
}

impl Histogram {
    pub fn observe(&self, d: Duration) {
        let ms = d.as_millis() as u64;
        let idx = BUCKET_MS.iter().position(|&b| ms <= b).unwrap_or(BUCKET_MS.len());
        self.buckets[idx].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_us.fetch_add(d.as_micros() as u64, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn mean_ms(&self) -> f64 {
        let c = self.count();
        if c == 0 {
            return 0.0;
        }
        self.sum_us.load(Ordering::Relaxed) as f64 / 1000.0 / c as f64
    }

    /// Approximate quantile from bucket boundaries. Buckets `0..12`
    /// report their upper bound; the overflow bucket reports its
    /// *lower* bound (the last finite boundary) — the histogram only
    /// knows the observation exceeded it, so any larger value would be
    /// an invented precision.
    pub fn quantile_ms(&self, q: f64) -> f64 {
        let total = self.count();
        if total == 0 {
            return 0.0;
        }
        let target = (q * total as f64).ceil() as u64;
        let mut seen = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            seen += b.load(Ordering::Relaxed);
            if seen >= target {
                return *BUCKET_MS.get(i).unwrap_or(BUCKET_MS.last().unwrap()) as f64;
            }
        }
        *BUCKET_MS.last().unwrap() as f64
    }

    /// The finite bucket boundaries (upper bounds, ms); the implicit
    /// 13th bucket collects everything above the last entry.
    pub fn bucket_bounds_ms() -> &'static [u64] {
        &BUCKET_MS
    }

    /// Per-bucket observation counts (12 bounded buckets + overflow).
    pub fn bucket_counts(&self) -> Vec<u64> {
        self.buckets.iter().map(|b| b.load(Ordering::Relaxed)).collect()
    }

    /// Self-describing JSON export: boundaries ride along with the
    /// counts so consumers never have to hard-code the bucket layout.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            (
                "bounds_ms",
                Json::Arr(BUCKET_MS.iter().map(|&b| Json::Num(b as f64)).collect()),
            ),
            (
                "counts",
                Json::Arr(self.bucket_counts().into_iter().map(|c| Json::Num(c as f64)).collect()),
            ),
            ("count", Json::Num(self.count() as f64)),
            ("mean_ms", Json::Num(self.mean_ms())),
            ("p50_ms", Json::Num(self.quantile_ms(0.5))),
            ("p95_ms", Json::Num(self.quantile_ms(0.95))),
            ("p99_ms", Json::Num(self.quantile_ms(0.99))),
        ])
    }
}

/// All coordinator metrics.
#[derive(Default)]
pub struct Metrics {
    pub jobs_submitted: AtomicU64,
    pub jobs_completed: AtomicU64,
    pub jobs_rejected: AtomicU64,
    pub jobs_failed: AtomicU64,
    /// Submissions bounced because the pending queue was at capacity.
    pub jobs_overloaded: AtomicU64,
    /// Jobs whose deadline passed before (or at) lane pickup, plus
    /// submissions rejected as deadline-infeasible up front.
    pub jobs_expired: AtomicU64,
    /// Queued jobs bounced by a server drain (no engine work done).
    pub jobs_cancelled: AtomicU64,
    /// Submissions answered from the idempotent-token table: a retry
    /// re-attached to an existing job instead of fitting again.
    pub jobs_deduped: AtomicU64,
    pub job_latency: Histogram,
}

impl Metrics {
    pub fn summary(&self) -> String {
        format!(
            "jobs: submitted={} completed={} rejected={} failed={} overloaded={} expired={} \
             cancelled={} deduped={} | latency mean={:.1}ms p50≤{:.0}ms p95≤{:.0}ms",
            self.jobs_submitted.load(Ordering::Relaxed),
            self.jobs_completed.load(Ordering::Relaxed),
            self.jobs_rejected.load(Ordering::Relaxed),
            self.jobs_failed.load(Ordering::Relaxed),
            self.jobs_overloaded.load(Ordering::Relaxed),
            self.jobs_expired.load(Ordering::Relaxed),
            self.jobs_cancelled.load(Ordering::Relaxed),
            self.jobs_deduped.load(Ordering::Relaxed),
            self.job_latency.mean_ms(),
            self.job_latency.quantile_ms(0.5),
            self.job_latency.quantile_ms(0.95),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_quantiles() {
        let h = Histogram::default();
        for ms in [1u64, 3, 7, 20, 20, 40, 90, 400, 900, 2000] {
            h.observe(Duration::from_millis(ms));
        }
        assert_eq!(h.count(), 10);
        assert!(h.mean_ms() > 100.0);
        assert!(h.quantile_ms(0.5) <= 50.0);
        assert!(h.quantile_ms(0.95) >= 500.0);
        assert!(h.quantile_ms(1.0) >= h.quantile_ms(0.1));
    }

    #[test]
    fn empty_histogram() {
        let h = Histogram::default();
        assert_eq!(h.mean_ms(), 0.0);
        assert_eq!(h.quantile_ms(0.9), 0.0);
    }

    #[test]
    fn overflow_bucket_reports_its_lower_bound() {
        // Observations past the last finite bound must report that
        // bound (the overflow bucket's lower edge), not an invented
        // larger number.
        let h = Histogram::default();
        h.observe(Duration::from_millis(45_000));
        h.observe(Duration::from_millis(120_000));
        let last = *Histogram::bucket_bounds_ms().last().unwrap() as f64;
        assert_eq!(h.quantile_ms(0.5), last);
        assert_eq!(h.quantile_ms(1.0), last);
        let counts = h.bucket_counts();
        assert_eq!(counts.len(), Histogram::bucket_bounds_ms().len() + 1);
        assert_eq!(*counts.last().unwrap(), 2);
    }

    #[test]
    fn histogram_json_is_self_describing() {
        let h = Histogram::default();
        h.observe(Duration::from_millis(3));
        h.observe(Duration::from_millis(700));
        let j = h.to_json();
        let s = j.to_string_json();
        let back = Json::parse(&s).expect("histogram JSON must reparse");
        let bounds = match back.get("bounds_ms") {
            Some(Json::Arr(a)) => a.len(),
            _ => panic!("missing bounds_ms"),
        };
        let counts = match back.get("counts") {
            Some(Json::Arr(a)) => a.len(),
            _ => panic!("missing counts"),
        };
        assert_eq!(counts, bounds + 1, "counts carry the overflow bucket");
        assert_eq!(back.get("count").and_then(Json::as_f64), Some(2.0));
    }
}
