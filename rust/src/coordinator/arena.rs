//! Ciphertext slot arena: a slab allocator for working-set ciphertexts.
//!
//! The FHE working set is large (one ciphertext is `2·L·d·8` bytes;
//! a GD iteration materialises `N + N·P` intermediates), so the
//! coordinator tracks them in a reusable slab rather than letting each
//! job churn the global allocator — the KV-cache-manager analogue of a
//! serving stack. The arena reports high-water occupancy for the fig5
//! memory accounting.
//!
//! The byte-budgeted LRU that used to live here moved to
//! [`crate::util::lru`] so its accounting invariants can be property-
//! and concurrency-tested as plain util code; the re-export below keeps
//! existing `coordinator::arena::LruBytes` paths compiling.

use crate::fhe::Ciphertext;

pub use crate::util::lru::LruBytes;

/// Slot handle.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SlotId(usize);

/// Slab of ciphertext slots with a free list.
#[derive(Default)]
pub struct CtArena {
    slots: Vec<Option<Ciphertext>>,
    free: Vec<usize>,
    /// Peak number of live ciphertexts.
    high_water: usize,
    /// Peak live bytes.
    high_water_bytes: usize,
    live_bytes: usize,
}

impl CtArena {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn insert(&mut self, ct: Ciphertext) -> SlotId {
        self.live_bytes += ct.size_bytes();
        let id = match self.free.pop() {
            Some(i) => {
                self.slots[i] = Some(ct);
                i
            }
            None => {
                self.slots.push(Some(ct));
                self.slots.len() - 1
            }
        };
        self.high_water = self.high_water.max(self.len());
        self.high_water_bytes = self.high_water_bytes.max(self.live_bytes);
        SlotId(id)
    }

    pub fn get(&self, id: SlotId) -> &Ciphertext {
        self.slots[id.0].as_ref().expect("use after free")
    }

    pub fn take(&mut self, id: SlotId) -> Ciphertext {
        let ct = self.slots[id.0].take().expect("double free");
        self.live_bytes -= ct.size_bytes();
        self.free.push(id.0);
        ct
    }

    pub fn release(&mut self, id: SlotId) {
        let _ = self.take(id);
    }

    /// Live ciphertext count.
    pub fn len(&self) -> usize {
        self.slots.len() - self.free.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn high_water(&self) -> usize {
        self.high_water
    }

    pub fn high_water_bytes(&self) -> usize {
        self.high_water_bytes
    }

    /// Capacity actually allocated (slots ever created).
    pub fn capacity(&self) -> usize {
        self.slots.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::math::poly::{Rep, RnsPoly};

    fn dummy_ct(d: usize) -> Ciphertext {
        let p = RnsPoly { d, planes: vec![vec![0; d]; 2], rep: Rep::Coeff };
        Ciphertext::new(vec![p.clone(), p])
    }

    #[test]
    fn insert_get_take() {
        let mut a = CtArena::new();
        let id = a.insert(dummy_ct(8));
        assert_eq!(a.len(), 1);
        assert_eq!(a.get(id).len(), 2);
        let ct = a.take(id);
        assert_eq!(ct.len(), 2);
        assert!(a.is_empty());
    }

    #[test]
    fn slots_are_reused() {
        let mut a = CtArena::new();
        let ids: Vec<SlotId> = (0..10).map(|_| a.insert(dummy_ct(8))).collect();
        assert_eq!(a.capacity(), 10);
        for id in ids {
            a.release(id);
        }
        for _ in 0..10 {
            a.insert(dummy_ct(8));
        }
        assert_eq!(a.capacity(), 10, "freed slots must be reused");
        assert_eq!(a.high_water(), 10);
    }

    #[test]
    fn high_water_tracks_bytes() {
        let mut a = CtArena::new();
        let id1 = a.insert(dummy_ct(16));
        let bytes1 = a.high_water_bytes();
        a.release(id1);
        let _ = a.insert(dummy_ct(8));
        assert_eq!(a.high_water_bytes(), bytes1, "peak persists after release");
        assert!(a.high_water_bytes() > 0);
    }

    #[test]
    #[should_panic(expected = "double free")]
    fn double_free_panics() {
        let mut a = CtArena::new();
        let id = a.insert(dummy_ct(8));
        a.release(id);
        a.release(id);
    }
}
