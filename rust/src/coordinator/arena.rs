//! Ciphertext slot arena: a slab allocator for working-set ciphertexts,
//! plus a byte-budgeted LRU ([`LruBytes`]) backing the per-tenant
//! operand caches.
//!
//! The FHE working set is large (one ciphertext is `2·L·d·8` bytes;
//! a GD iteration materialises `N + N·P` intermediates), so the
//! coordinator tracks them in a reusable slab rather than letting each
//! job churn the global allocator — the KV-cache-manager analogue of a
//! serving stack. The arena reports high-water occupancy for the fig5
//! memory accounting.

use std::collections::BTreeMap;

use crate::fhe::Ciphertext;

/// Slot handle.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SlotId(usize);

/// Slab of ciphertext slots with a free list.
#[derive(Default)]
pub struct CtArena {
    slots: Vec<Option<Ciphertext>>,
    free: Vec<usize>,
    /// Peak number of live ciphertexts.
    high_water: usize,
    /// Peak live bytes.
    high_water_bytes: usize,
    live_bytes: usize,
}

impl CtArena {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn insert(&mut self, ct: Ciphertext) -> SlotId {
        self.live_bytes += ct.size_bytes();
        let id = match self.free.pop() {
            Some(i) => {
                self.slots[i] = Some(ct);
                i
            }
            None => {
                self.slots.push(Some(ct));
                self.slots.len() - 1
            }
        };
        self.high_water = self.high_water.max(self.len());
        self.high_water_bytes = self.high_water_bytes.max(self.live_bytes);
        SlotId(id)
    }

    pub fn get(&self, id: SlotId) -> &Ciphertext {
        self.slots[id.0].as_ref().expect("use after free")
    }

    pub fn take(&mut self, id: SlotId) -> Ciphertext {
        let ct = self.slots[id.0].take().expect("double free");
        self.live_bytes -= ct.size_bytes();
        self.free.push(id.0);
        ct
    }

    pub fn release(&mut self, id: SlotId) {
        let _ = self.take(id);
    }

    /// Live ciphertext count.
    pub fn len(&self) -> usize {
        self.slots.len() - self.free.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn high_water(&self) -> usize {
        self.high_water
    }

    pub fn high_water_bytes(&self) -> usize {
        self.high_water_bytes
    }

    /// Capacity actually allocated (slots ever created).
    pub fn capacity(&self) -> usize {
        self.slots.len()
    }
}

// ---- byte-budgeted LRU --------------------------------------------------

struct LruEntry<V> {
    value: V,
    bytes: usize,
    tick: u64,
}

/// Byte-budgeted LRU map. Recency is a monotone tick stamped on every
/// `get` hit and `insert`; when the live byte total exceeds the budget,
/// the minimum-tick entry is evicted (but the most recent insert is
/// never evicted, so a single over-budget value still caches). Keys are
/// exact — the per-tenant operand caches key on canonical plaintext
/// coefficient words, because an approximate (hashed) key colliding
/// would silently substitute a *wrong operand* into an encrypted fit.
pub struct LruBytes<K: Ord + Clone, V> {
    entries: BTreeMap<K, LruEntry<V>>,
    budget_bytes: usize,
    live_bytes: usize,
    tick: u64,
    hits: u64,
    misses: u64,
    evictions: u64,
}

impl<K: Ord + Clone, V> LruBytes<K, V> {
    pub fn new(budget_bytes: usize) -> Self {
        LruBytes {
            entries: BTreeMap::new(),
            budget_bytes,
            live_bytes: 0,
            tick: 0,
            hits: 0,
            misses: 0,
            evictions: 0,
        }
    }

    fn next_tick(&mut self) -> u64 {
        self.tick += 1;
        self.tick
    }

    /// Look up `key`, bumping its recency on a hit.
    pub fn get(&mut self, key: &K) -> Option<&V> {
        let tick = self.tick + 1;
        match self.entries.get_mut(key) {
            Some(e) => {
                self.tick = tick;
                e.tick = tick;
                self.hits += 1;
                Some(&e.value)
            }
            None => {
                self.misses += 1;
                None
            }
        }
    }

    /// Insert (or replace) an entry charged at `bytes`, then evict
    /// least-recently-used entries until the budget holds again. The
    /// just-inserted entry is exempt from its own eviction pass.
    pub fn insert(&mut self, key: K, value: V, bytes: usize) {
        let tick = self.next_tick();
        if let Some(old) = self.entries.insert(key, LruEntry { value, bytes, tick }) {
            self.live_bytes -= old.bytes;
        }
        self.live_bytes += bytes;
        while self.live_bytes > self.budget_bytes && self.entries.len() > 1 {
            let victim = self
                .entries
                .iter()
                .min_by_key(|(_, e)| e.tick)
                .map(|(k, _)| k.clone())
                .expect("non-empty");
            if let Some(e) = self.entries.remove(&victim) {
                self.live_bytes -= e.bytes;
                self.evictions += 1;
            }
        }
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn live_bytes(&self) -> usize {
        self.live_bytes
    }

    pub fn budget_bytes(&self) -> usize {
        self.budget_bytes
    }

    /// `(hits, misses, evictions)` since construction.
    pub fn stats(&self) -> (u64, u64, u64) {
        (self.hits, self.misses, self.evictions)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::math::poly::{Rep, RnsPoly};

    fn dummy_ct(d: usize) -> Ciphertext {
        let p = RnsPoly { d, planes: vec![vec![0; d]; 2], rep: Rep::Coeff };
        Ciphertext::new(vec![p.clone(), p])
    }

    #[test]
    fn insert_get_take() {
        let mut a = CtArena::new();
        let id = a.insert(dummy_ct(8));
        assert_eq!(a.len(), 1);
        assert_eq!(a.get(id).len(), 2);
        let ct = a.take(id);
        assert_eq!(ct.len(), 2);
        assert!(a.is_empty());
    }

    #[test]
    fn slots_are_reused() {
        let mut a = CtArena::new();
        let ids: Vec<SlotId> = (0..10).map(|_| a.insert(dummy_ct(8))).collect();
        assert_eq!(a.capacity(), 10);
        for id in ids {
            a.release(id);
        }
        for _ in 0..10 {
            a.insert(dummy_ct(8));
        }
        assert_eq!(a.capacity(), 10, "freed slots must be reused");
        assert_eq!(a.high_water(), 10);
    }

    #[test]
    fn high_water_tracks_bytes() {
        let mut a = CtArena::new();
        let id1 = a.insert(dummy_ct(16));
        let bytes1 = a.high_water_bytes();
        a.release(id1);
        let _ = a.insert(dummy_ct(8));
        assert_eq!(a.high_water_bytes(), bytes1, "peak persists after release");
        assert!(a.high_water_bytes() > 0);
    }

    #[test]
    #[should_panic(expected = "double free")]
    fn double_free_panics() {
        let mut a = CtArena::new();
        let id = a.insert(dummy_ct(8));
        a.release(id);
        a.release(id);
    }

    #[test]
    fn lru_evicts_oldest_under_byte_budget() {
        let mut lru: LruBytes<u32, &'static str> = LruBytes::new(100);
        lru.insert(1, "a", 40);
        lru.insert(2, "b", 40);
        lru.insert(3, "c", 40); // 120 > 100 ⇒ evict key 1
        assert_eq!(lru.len(), 2);
        assert!(lru.get(&1).is_none());
        assert_eq!(lru.get(&2), Some(&"b"));
        assert_eq!(lru.get(&3), Some(&"c"));
        assert_eq!(lru.live_bytes(), 80);
        let (hits, misses, evictions) = lru.stats();
        assert_eq!((hits, misses, evictions), (2, 1, 1));
    }

    #[test]
    fn lru_hit_bumps_recency() {
        let mut lru: LruBytes<u32, u32> = LruBytes::new(100);
        lru.insert(1, 10, 40);
        lru.insert(2, 20, 40);
        assert_eq!(lru.get(&1), Some(&10)); // key 1 is now the freshest
        lru.insert(3, 30, 40); // over budget ⇒ evict key 2, not key 1
        assert_eq!(lru.get(&2), None);
        assert_eq!(lru.get(&1), Some(&10));
        assert_eq!(lru.get(&3), Some(&30));
    }

    #[test]
    fn lru_single_oversized_entry_survives() {
        // One value larger than the whole budget must still cache (the
        // just-inserted entry is exempt from its own eviction pass).
        let mut lru: LruBytes<u32, u32> = LruBytes::new(10);
        lru.insert(1, 1, 50);
        assert_eq!(lru.len(), 1);
        assert_eq!(lru.get(&1), Some(&1));
        lru.insert(2, 2, 50); // displaces the previous oversized entry
        assert_eq!(lru.len(), 1);
        assert_eq!(lru.get(&2), Some(&2));
    }

    #[test]
    fn lru_replace_accounts_bytes_once() {
        let mut lru: LruBytes<u32, u32> = LruBytes::new(100);
        lru.insert(1, 10, 60);
        lru.insert(1, 11, 30);
        assert_eq!(lru.live_bytes(), 30);
        assert_eq!(lru.get(&1), Some(&11));
    }
}
