//! TCP service: line-delimited JSON requests against a [`Coordinator`].
//!
//! Every request and reply carries the wire schema version `"v"`
//! (currently [`proto::PROTOCOL_VERSION`]); the server answers any
//! other (or missing) version with code `bad_version` instead of
//! mis-parsing a future schema. Requests (one JSON object per line):
//!
//! - `{"v":1,"type":"submit","data":{...},"cfg":{...}}` with optional
//!   `"tenant":"name"`, `"deadline_ms":N` and idempotency `"token":"s"`
//!   → `{"v":1,"ok":true,"id":N}` (a resubmitted `(tenant, token)`
//!   re-attaches to the original job instead of fitting again)
//! - `{"v":1,"type":"status","id":N}` → `{"v":1,"ok":true,"state":"running"}`
//! - `{"v":1,"type":"result","id":N}` → `{"v":1,"ok":true,"fit":{...}}`
//!   (waits; the job stays tracked so a retry after a lost reply can
//!   fetch it again — `ack` releases it)
//! - `{"v":1,"type":"ack","id":N}` → `{"v":1,"ok":true,"released":bool}`
//! - `{"v":1,"type":"health"}` → `{"v":1,"ok":true,"accepting":bool,
//!   "lanes":N,"queue_depth":N,"running":N,"tracked_jobs":N,
//!   "timers_live":N,"uptime_ms":N,"journal":bool,"recovered":N}` —
//!   `journal` says whether the coordinator is journal-backed,
//!   `recovered` counts jobs rebuilt from the journal at startup
//!   (requeued + restored + failed; 0 for fresh or journal-less starts)
//! - `{"v":1,"type":"shutdown"}` with optional `"drain_ms":N` (default
//!   10000) → `{"v":1,"ok":true,"bounced":N,"drained":bool}` — stops
//!   admission, bounces queued jobs (`shutting_down`), drains in-flight
//! - `{"v":1,"type":"metrics"}` → `{"v":1,"ok":true,"summary":"...",
//!   "stats":{...},"snapshot":{...},"histogram":{...},"tenants":[...]}`
//!   — `snapshot` is the unified
//!   [`MetricsSnapshot`](crate::util::telemetry::MetricsSnapshot)
//!   document (schema `els-metrics-v1`), `histogram` the job-latency
//!   histogram, `tenants` the per-tenant cache/counter registry
//! - `{"v":1,"type":"ping"}` → `{"v":1,"ok":true}`
//!
//! Error replies are `{"v":1,"ok":false,"code":"...","error":"..."}`
//! with `code` one of the structured [`proto::ErrorCode`] values;
//! [`Client`] surfaces them as typed [`WireError`]s (transport
//! failures map to code `transport`, with the io incident class —
//! connect-refused, connection-reset, truncated-frame, … — prefixed
//! onto the message so retry policies and operators can tell them
//! apart). The `wire_read`/`wire_write` chaos sites
//! ([`crate::util::faults`]) inject io faults, mid-frame disconnects
//! and partial writes at this layer.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

use crate::util::error::{Context, Result};

use crate::coordinator::job::{JobId, JobSpec};
use crate::coordinator::protocol as proto;
use crate::coordinator::protocol::{ErrorCode, WireError, WireResult};
use crate::coordinator::scheduler::Coordinator;
use crate::coordinator::tenant::TenantId;
use crate::els::encrypted::EncryptedFit;
use crate::els::model::EncryptedDataset;
use crate::util::faults::{self, FaultKind, FaultSite};
use crate::util::json::Json;
use crate::util::telemetry::{self, MetricsSnapshot, Phase};

/// Running server handle.
pub struct Server {
    pub addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl Server {
    /// Bind and serve on a background thread. `addr` may use port 0 for
    /// an ephemeral port (see `self.addr`).
    pub fn start(coord: Arc<Coordinator>, addr: &str) -> Result<Server> {
        let listener = TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
        let local = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = stop.clone();
        let handle = std::thread::Builder::new()
            .name("els-server".into())
            .spawn(move || {
                while !stop2.load(Ordering::Relaxed) {
                    match listener.accept() {
                        Ok((stream, _)) => {
                            let coord = coord.clone();
                            std::thread::spawn(move || {
                                let _ = handle_conn(stream, coord);
                            });
                        }
                        Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                            std::thread::sleep(Duration::from_millis(5));
                        }
                        Err(_) => break,
                    }
                }
            })?;
        Ok(Server { addr: local, stop, handle: Some(handle) })
    }

    pub fn stop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.stop();
    }
}

/// The shared `("v", "ok")` prefix of every reply.
fn reply_base(ok: bool) -> Vec<(&'static str, Json)> {
    vec![
        ("v", Json::Num(proto::PROTOCOL_VERSION as f64)),
        ("ok", Json::Bool(ok)),
    ]
}

fn handle_conn(stream: TcpStream, coord: Arc<Coordinator>) -> Result<()> {
    stream.set_nodelay(true).ok();
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut writer = stream;
    let mut line = String::new();
    loop {
        line.clear();
        if reader.read_line(&mut line)? == 0 {
            return Ok(()); // client closed
        }
        // Chaos `wire_read`: the request dies *before* handling, as if
        // the socket failed mid-read — nothing was admitted, so a
        // client retry is always safe here.
        match faults::check(FaultSite::WireRead) {
            Some(FaultKind::Disconnect) => return Ok(()),
            Some(FaultKind::IoError) => {
                return Err(std::io::Error::new(
                    std::io::ErrorKind::ConnectionReset,
                    "injected wire read fault",
                )
                .into());
            }
            _ => {}
        }
        // One span per request: handling + reply serialisation.
        let _span = telemetry::span(Phase::ServeReply);
        let response = match handle_request(&coord, line.trim()) {
            Ok(j) => j,
            Err(e) => {
                let mut fields = reply_base(false);
                fields.push(("code", Json::str(e.code.as_str())));
                fields.push(("error", Json::str(&e.message)));
                Json::obj(fields)
            }
        };
        let frame = response.to_string_json();
        // Chaos `wire_write`: the request WAS processed but the reply
        // is lost or mangled — exactly the window idempotent submit
        // tokens and the peek-then-ack result protocol exist for.
        match faults::check(FaultSite::WireWrite) {
            Some(FaultKind::Disconnect) => return Ok(()),
            Some(FaultKind::PartialWrite) => {
                let bytes = frame.as_bytes();
                writer.write_all(&bytes[..bytes.len() / 2])?;
                return Ok(()); // close without the newline terminator
            }
            Some(FaultKind::IoError) => {
                return Err(std::io::Error::new(
                    std::io::ErrorKind::BrokenPipe,
                    "injected wire write fault",
                )
                .into());
            }
            _ => {}
        }
        writer.write_all(frame.as_bytes())?;
        writer.write_all(b"\n")?;
    }
}

/// Flatten a codec/decode failure into a `bad_request` wire error.
fn bad<T>(r: Result<T>) -> WireResult<T> {
    r.map_err(|e| WireError::bad_request(format!("{e:#}")))
}

/// A required request field, or `bad_request`.
fn field<'a>(req: &'a Json, key: &str) -> WireResult<&'a Json> {
    req.get(key).ok_or_else(|| WireError::bad_request(format!("missing field '{key}'")))
}

/// The required numeric `"id"` field as a [`JobId`].
fn job_id(req: &Json) -> WireResult<JobId> {
    Ok(JobId(
        field(req, "id")?
            .as_u64()
            .ok_or_else(|| WireError::bad_request("'id' must be a number"))?,
    ))
}

fn handle_request(coord: &Arc<Coordinator>, line: &str) -> WireResult<Json> {
    let req = Json::parse(line)
        .map_err(|e| WireError::bad_request(format!("malformed request JSON: {e:#}")))?;
    // Version gate before anything else: a future schema must bounce
    // cleanly, not half-parse.
    let v = req.get("v").and_then(Json::as_u64);
    if v != Some(proto::PROTOCOL_VERSION) {
        let got = v.map(|x| x.to_string()).unwrap_or_else(|| "absent".into());
        return Err(WireError::new(
            ErrorCode::BadVersion,
            format!("request v={got}, server speaks v={}", proto::PROTOCOL_VERSION),
        ));
    }
    let typ = field(&req, "type")?
        .as_str()
        .ok_or_else(|| WireError::bad_request("'type' must be a string"))?;
    match typ {
        "ping" => Ok(Json::obj(reply_base(true))),
        "submit" => {
            let ctx = coord.engine().ctx();
            let data = bad(proto::dataset_from_json(ctx, field(&req, "data")?))?;
            let (cfg, cd_updates) = bad(proto::cfg_from_json(field(&req, "cfg")?))?;
            let mut spec = JobSpec::new(data, cfg, cd_updates);
            if let Some(tenant) = req.get("tenant").and_then(Json::as_str) {
                spec = spec.with_tenant(TenantId::new(tenant));
            }
            if let Some(ms) = req.get("deadline_ms").and_then(Json::as_u64) {
                spec = spec.with_deadline_ms(ms);
            }
            if let Some(tok) = req.get("token").and_then(Json::as_str) {
                spec = spec.with_token(tok);
            }
            let id = coord.submit(spec)?;
            let mut fields = reply_base(true);
            fields.push(("id", Json::Num(id.0 as f64)));
            Ok(Json::obj(fields))
        }
        "status" => {
            let id = job_id(&req)?;
            let state = coord.state(id).ok_or_else(|| {
                WireError::new(ErrorCode::UnknownJob, format!("unknown job {id}"))
            })?;
            let mut fields = reply_base(true);
            fields.push(("state", Json::str(&state)));
            Ok(Json::obj(fields))
        }
        "result" => {
            // Peek, don't take: the job stays tracked so a retry after
            // a lost reply can fetch the same fit again. `ack` (below)
            // is what finally releases it.
            let id = job_id(&req)?;
            coord.wait(id, Duration::from_secs(3600))?;
            let fit = coord.peek_result(id)?;
            let mut fields = reply_base(true);
            fields.push(("fit", proto::fit_to_json(&fit)));
            Ok(Json::obj(fields))
        }
        "ack" => {
            let id = job_id(&req)?;
            let mut fields = reply_base(true);
            fields.push(("released", Json::Bool(coord.release(id))));
            Ok(Json::obj(fields))
        }
        "health" => {
            let mut fields = reply_base(true);
            fields.push(("accepting", Json::Bool(coord.is_accepting())));
            fields.push(("lanes", Json::Num(coord.lanes() as f64)));
            fields.push(("queue_depth", Json::Num(coord.queue_depth() as f64)));
            fields.push(("running", Json::Num(coord.running_jobs() as f64)));
            fields.push(("tracked_jobs", Json::Num(coord.tracked_jobs() as f64)));
            fields.push(("timers_live", Json::Num(coord.timers_live() as f64)));
            fields.push(("uptime_ms", Json::Num(coord.uptime().as_millis() as f64)));
            fields.push(("journal", Json::Bool(coord.journal().is_some())));
            fields.push(("recovered", Json::Num(coord.recovered().total() as f64)));
            Ok(Json::obj(fields))
        }
        "shutdown" => {
            let drain_ms = req.get("drain_ms").and_then(Json::as_u64).unwrap_or(10_000);
            let report = coord.shutdown(Duration::from_millis(drain_ms));
            let mut fields = reply_base(true);
            fields.push(("bounced", Json::Num(report.bounced as f64)));
            fields.push(("drained", Json::Bool(report.drained)));
            Ok(Json::obj(fields))
        }
        "metrics" => {
            let (muls, plains, adds, batches) = coord.engine().stats().snapshot();
            let snapshot =
                MetricsSnapshot::capture(coord.engine().ctx(), coord.engine().stats())
                    .with_coordinator(&coord.metrics);
            let mut fields = reply_base(true);
            fields.push(("summary", Json::str(&coord.metrics.summary())));
            fields.push((
                "stats",
                Json::obj(vec![
                    ("ct_muls", Json::Num(muls as f64)),
                    ("plain_muls", Json::Num(plains as f64)),
                    ("adds", Json::Num(adds as f64)),
                    ("batches", Json::Num(batches as f64)),
                ]),
            ));
            fields.push(("snapshot", snapshot.to_json()));
            fields.push(("histogram", coord.metrics.job_latency.to_json()));
            fields.push(("tenants", coord.tenants().to_json()));
            Ok(Json::obj(fields))
        }
        other => Err(WireError::bad_request(format!("unknown request type '{other}'"))),
    }
}

/// Blocking client for the wire protocol. Every method returns a typed
/// [`WireResult`]: server rejections keep their structured code,
/// connect/read/write/parse failures map to [`ErrorCode::Transport`].
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

/// Classify an io error into the transport incident taxonomy. All of
/// these map to code `transport`, but a connect-refused (server down)
/// reads very differently from a truncated frame (server died
/// mid-reply) in logs and retry decisions, so the class prefixes the
/// message.
fn transport_class(e: &std::io::Error) -> &'static str {
    use std::io::ErrorKind as K;
    match e.kind() {
        K::ConnectionRefused => "connect-refused",
        K::ConnectionReset => "connection-reset",
        K::ConnectionAborted => "connection-aborted",
        K::BrokenPipe => "broken-pipe",
        K::UnexpectedEof => "truncated-frame",
        K::TimedOut | K::WouldBlock => "timeout",
        _ => "io",
    }
}

fn transport(e: std::io::Error) -> WireError {
    WireError::transport(format!("{}: {e}", transport_class(&e)))
}

impl Client {
    pub fn connect(addr: &str) -> WireResult<Client> {
        let stream = TcpStream::connect(addr).map_err(|e| {
            WireError::transport(format!("{}: connecting {addr}: {e}", transport_class(&e)))
        })?;
        stream.set_nodelay(true).ok();
        let reader = BufReader::new(stream.try_clone().map_err(transport)?);
        Ok(Client { reader, writer: stream })
    }

    /// One request/reply round-trip; `fields` ride alongside the
    /// always-present `"v"` and `"type"`.
    fn call(&mut self, typ: &str, mut fields: Vec<(&'static str, Json)>) -> WireResult<Json> {
        let mut all = vec![
            ("v", Json::Num(proto::PROTOCOL_VERSION as f64)),
            ("type", Json::str(typ)),
        ];
        all.append(&mut fields);
        let req = Json::obj(all);
        self.writer.write_all(req.to_string_json().as_bytes()).map_err(transport)?;
        self.writer.write_all(b"\n").map_err(transport)?;
        let mut line = String::new();
        let n = self.reader.read_line(&mut line).map_err(transport)?;
        if n == 0 {
            return Err(WireError::transport("disconnected: server closed the connection"));
        }
        if !line.ends_with('\n') {
            // A frame without its newline terminator means the server
            // (or the wire) died mid-reply — distinct from a clean
            // close and from a malformed-but-complete response.
            return Err(WireError::transport(format!(
                "truncated-frame: reply ended mid-frame after {n} bytes"
            )));
        }
        let resp = Json::parse(line.trim())
            .map_err(|e| WireError::transport(format!("malformed response: {e:#}")))?;
        if resp.get("ok").and_then(|v| v.as_bool()) == Some(true) {
            return Ok(resp);
        }
        let code = resp
            .get("code")
            .and_then(|c| c.as_str())
            .and_then(ErrorCode::from_str)
            .unwrap_or(ErrorCode::Internal);
        let msg = resp
            .get("error")
            .and_then(|e| e.as_str())
            .unwrap_or("unknown server error");
        Err(WireError::new(code, msg))
    }

    pub fn ping(&mut self) -> WireResult<()> {
        self.call("ping", vec![]).map(|_| ())
    }

    /// Submit under the default tenant with no deadline.
    pub fn submit(
        &mut self,
        data: &EncryptedDataset,
        cfg: &crate::els::encrypted::FitConfig,
        cd_updates: Option<usize>,
    ) -> WireResult<JobId> {
        self.submit_with(data, cfg, cd_updates, None, None)
    }

    /// Submit with an explicit tenant and/or deadline.
    pub fn submit_with(
        &mut self,
        data: &EncryptedDataset,
        cfg: &crate::els::encrypted::FitConfig,
        cd_updates: Option<usize>,
        tenant: Option<&str>,
        deadline_ms: Option<u64>,
    ) -> WireResult<JobId> {
        self.submit_opts(data, cfg, cd_updates, tenant, deadline_ms, None)
    }

    /// Full-control submit: tenant, deadline and an idempotency token.
    /// Resubmitting the same `(tenant, token)` — e.g. retrying after a
    /// lost reply — re-attaches to the original job without a second
    /// fit.
    pub fn submit_opts(
        &mut self,
        data: &EncryptedDataset,
        cfg: &crate::els::encrypted::FitConfig,
        cd_updates: Option<usize>,
        tenant: Option<&str>,
        deadline_ms: Option<u64>,
        token: Option<&str>,
    ) -> WireResult<JobId> {
        let mut fields = vec![
            ("data", proto::dataset_to_json(data)),
            ("cfg", proto::cfg_to_json(cfg, cd_updates)),
        ];
        if let Some(t) = tenant {
            fields.push(("tenant", Json::str(t)));
        }
        if let Some(ms) = deadline_ms {
            fields.push(("deadline_ms", Json::Num(ms as f64)));
        }
        if let Some(tok) = token {
            fields.push(("token", Json::str(tok)));
        }
        let resp = self.call("submit", fields)?;
        let id = resp
            .get("id")
            .and_then(Json::as_u64)
            .ok_or_else(|| WireError::transport("reply missing 'id'"))?;
        Ok(JobId(id))
    }

    pub fn status(&mut self, id: JobId) -> WireResult<String> {
        let resp = self.call("status", vec![("id", Json::Num(id.0 as f64))])?;
        resp.get("state")
            .and_then(|s| s.as_str())
            .map(str::to_string)
            .ok_or_else(|| WireError::transport("reply missing 'state'"))
    }

    /// Block until the job finishes and fetch the encrypted fit. On a
    /// successful decode the job is acked (released server-side)
    /// best-effort; a lost ack only means the job lingers until a later
    /// `ack`, never a client error.
    pub fn result(&mut self, ctx: &crate::fhe::FvContext, id: JobId) -> WireResult<EncryptedFit> {
        let resp = self.call("result", vec![("id", Json::Num(id.0 as f64))])?;
        let fit = resp
            .get("fit")
            .ok_or_else(|| WireError::transport("reply missing 'fit'"))?;
        let fit = proto::fit_from_json(ctx, fit)
            .map_err(|e| WireError::transport(format!("undecodable fit: {e:#}")))?;
        let _ = self.ack(id);
        Ok(fit)
    }

    /// Release a terminal job server-side (prunes its idempotency
    /// token). Returns whether anything was released.
    pub fn ack(&mut self, id: JobId) -> WireResult<bool> {
        let resp = self.call("ack", vec![("id", Json::Num(id.0 as f64))])?;
        Ok(resp.get("released").and_then(|b| b.as_bool()).unwrap_or(false))
    }

    /// The server's liveness/pressure report: `accepting`, `lanes`,
    /// `queue_depth`, `running`, `tracked_jobs`, `timers_live`,
    /// `uptime_ms`, `journal` (journal-backed?), `recovered` (jobs
    /// rebuilt from the journal at startup).
    pub fn health(&mut self) -> WireResult<Json> {
        self.call("health", vec![])
    }

    /// Ask the server to drain: admission stops, queued jobs bounce
    /// with code `shutting_down`, in-flight jobs finish (up to
    /// `drain_ms`, server default 10000). Returns `(bounced, drained)`.
    pub fn shutdown_server(&mut self, drain_ms: Option<u64>) -> WireResult<(u64, bool)> {
        let mut fields = Vec::new();
        if let Some(ms) = drain_ms {
            fields.push(("drain_ms", Json::Num(ms as f64)));
        }
        let resp = self.call("shutdown", fields)?;
        let bounced = resp
            .get("bounced")
            .and_then(Json::as_u64)
            .ok_or_else(|| WireError::transport("reply missing 'bounced'"))?;
        let drained = resp.get("drained").and_then(|b| b.as_bool()).unwrap_or(false);
        Ok((bounced, drained))
    }

    pub fn metrics(&mut self) -> WireResult<String> {
        let resp = self.call("metrics", vec![])?;
        resp.get("summary")
            .and_then(|s| s.as_str())
            .map(str::to_string)
            .ok_or_else(|| WireError::transport("reply missing 'summary'"))
    }

    /// Fetch the server's unified [`MetricsSnapshot`] JSON document
    /// (schema `els-metrics-v1`) — the machine-readable counterpart of
    /// [`metrics`](Self::metrics).
    pub fn metrics_snapshot(&mut self) -> WireResult<Json> {
        let resp = self.call("metrics", vec![])?;
        resp.get("snapshot")
            .cloned()
            .ok_or_else(|| WireError::transport("reply missing 'snapshot'"))
    }

    /// The whole metrics reply: `summary`, `stats`, `snapshot`,
    /// `histogram`, `tenants`.
    pub fn metrics_full(&mut self) -> WireResult<Json> {
        self.call("metrics", vec![])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::ErrorKind;

    #[test]
    fn transport_errors_carry_their_incident_class() {
        let cases = [
            (ErrorKind::ConnectionRefused, "connect-refused"),
            (ErrorKind::ConnectionReset, "connection-reset"),
            (ErrorKind::ConnectionAborted, "connection-aborted"),
            (ErrorKind::BrokenPipe, "broken-pipe"),
            (ErrorKind::UnexpectedEof, "truncated-frame"),
            (ErrorKind::TimedOut, "timeout"),
            (ErrorKind::NotFound, "io"),
        ];
        for (kind, class) in cases {
            let e = transport(std::io::Error::new(kind, "boom"));
            assert_eq!(e.code, ErrorCode::Transport);
            assert!(
                e.message.starts_with(&format!("{class}: ")),
                "{kind:?} must classify as {class}, got '{}'",
                e.message
            );
        }
    }

    #[test]
    fn connect_refused_is_classified_on_connect() {
        // Bind an ephemeral port, then free it: connecting afterwards
        // must refuse, and the client message must say so by class.
        let addr = {
            let l = TcpListener::bind("127.0.0.1:0").unwrap();
            l.local_addr().unwrap().to_string()
        };
        let err = Client::connect(&addr).expect_err("nothing is listening");
        assert_eq!(err.code, ErrorCode::Transport);
        assert!(
            err.message.starts_with("connect-refused: "),
            "got '{}'",
            err.message
        );
    }

    #[test]
    fn truncated_reply_frame_is_reported_as_such() {
        // A fake server that reads one request and replies with half a
        // frame (no newline) before closing: the client must report a
        // truncated frame, not a parse error or a clean close.
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let server = std::thread::spawn(move || {
            let (stream, _) = listener.accept().unwrap();
            let mut reader = BufReader::new(stream.try_clone().unwrap());
            let mut line = String::new();
            reader.read_line(&mut line).unwrap();
            let mut w = stream;
            w.write_all(b"{\"v\":1,\"ok\":tr").unwrap();
            // dropping closes the socket mid-frame
        });
        let mut client = Client::connect(&addr).unwrap();
        let err = client.ping().expect_err("frame was truncated");
        assert_eq!(err.code, ErrorCode::Transport);
        assert!(
            err.message.starts_with("truncated-frame: "),
            "got '{}'",
            err.message
        );
        server.join().unwrap();
    }

    #[test]
    fn clean_close_before_reply_reads_as_disconnect() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let server = std::thread::spawn(move || {
            let (stream, _) = listener.accept().unwrap();
            let mut reader = BufReader::new(stream.try_clone().unwrap());
            let mut line = String::new();
            reader.read_line(&mut line).unwrap();
            // close without writing anything
        });
        let mut client = Client::connect(&addr).unwrap();
        let err = client.ping().expect_err("server closed before replying");
        assert_eq!(err.code, ErrorCode::Transport);
        assert!(
            err.message.starts_with("disconnected: "),
            "got '{}'",
            err.message
        );
        server.join().unwrap();
    }
}
