//! TCP service: line-delimited JSON requests against a [`Coordinator`].
//!
//! Requests (one JSON object per line):
//! - `{"type":"submit","data":{...},"cfg":{...}}` → `{"ok":true,"id":N}`
//! - `{"type":"status","id":N}` → `{"ok":true,"state":"running"}`
//! - `{"type":"result","id":N}` → `{"ok":true,"fit":{...}}` (waits)
//! - `{"type":"metrics"}` → `{"ok":true,"summary":"...","stats":{...},
//!   "snapshot":{...}}` — `snapshot` is the unified
//!   [`MetricsSnapshot`](crate::util::telemetry::MetricsSnapshot)
//!   document (schema `els-metrics-v1`)
//! - `{"type":"ping"}` → `{"ok":true}`

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

use crate::util::error::{anyhow, Context, Result};

use crate::coordinator::job::JobId;
use crate::coordinator::protocol as proto;
use crate::coordinator::scheduler::Coordinator;
use crate::els::encrypted::EncryptedFit;
use crate::els::model::EncryptedDataset;
use crate::util::json::Json;
use crate::util::telemetry::{self, MetricsSnapshot, Phase};

/// Running server handle.
pub struct Server {
    pub addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl Server {
    /// Bind and serve on a background thread. `addr` may use port 0 for
    /// an ephemeral port (see `self.addr`).
    pub fn start(coord: Arc<Coordinator>, addr: &str) -> Result<Server> {
        let listener = TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
        let local = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = stop.clone();
        let handle = std::thread::Builder::new()
            .name("els-server".into())
            .spawn(move || {
                while !stop2.load(Ordering::Relaxed) {
                    match listener.accept() {
                        Ok((stream, _)) => {
                            let coord = coord.clone();
                            std::thread::spawn(move || {
                                let _ = handle_conn(stream, coord);
                            });
                        }
                        Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                            std::thread::sleep(Duration::from_millis(5));
                        }
                        Err(_) => break,
                    }
                }
            })?;
        Ok(Server { addr: local, stop, handle: Some(handle) })
    }

    pub fn stop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.stop();
    }
}

fn handle_conn(stream: TcpStream, coord: Arc<Coordinator>) -> Result<()> {
    stream.set_nodelay(true).ok();
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut writer = stream;
    let mut line = String::new();
    loop {
        line.clear();
        if reader.read_line(&mut line)? == 0 {
            return Ok(()); // client closed
        }
        // One span per request: handling + reply serialisation.
        let _span = telemetry::span(Phase::ServeReply);
        let response = match handle_request(&coord, line.trim()) {
            Ok(j) => j,
            Err(e) => Json::obj(vec![
                ("ok", Json::Bool(false)),
                ("error", Json::str(&format!("{e:#}"))),
            ]),
        };
        writer.write_all(response.to_string_json().as_bytes())?;
        writer.write_all(b"\n")?;
    }
}

fn handle_request(coord: &Arc<Coordinator>, line: &str) -> Result<Json> {
    let req = Json::parse(line).context("malformed request JSON")?;
    let typ = req.req("type")?.as_str().context("type")?;
    match typ {
        "ping" => Ok(Json::obj(vec![("ok", Json::Bool(true))])),
        "submit" => {
            let ctx = coord.engine().ctx();
            let data = proto::dataset_from_json(ctx, req.req("data")?)?;
            let (cfg, cd_updates) = proto::cfg_from_json(req.req("cfg")?)?;
            let id = coord.submit(crate::coordinator::job::JobSpec {
                data,
                cfg,
                cd_updates,
            })?;
            Ok(Json::obj(vec![("ok", Json::Bool(true)), ("id", Json::Num(id.0 as f64))]))
        }
        "status" => {
            let id = JobId(req.req("id")?.as_u64().context("id")?);
            let state = coord.state(id).ok_or_else(|| anyhow!("unknown job {id}"))?;
            Ok(Json::obj(vec![("ok", Json::Bool(true)), ("state", Json::str(&state))]))
        }
        "result" => {
            let id = JobId(req.req("id")?.as_u64().context("id")?);
            coord.wait(id, Duration::from_secs(3600))?;
            let fit = coord.take_result(id)?;
            Ok(Json::obj(vec![
                ("ok", Json::Bool(true)),
                ("fit", proto::fit_to_json(&fit)),
            ]))
        }
        "metrics" => {
            let (muls, plains, adds, batches) = coord.engine().stats().snapshot();
            let snapshot = MetricsSnapshot::capture(coord.engine().ctx(), coord.engine().stats())
                .with_coordinator(&coord.metrics);
            Ok(Json::obj(vec![
                ("ok", Json::Bool(true)),
                ("summary", Json::str(&coord.metrics.summary())),
                (
                    "stats",
                    Json::obj(vec![
                        ("ct_muls", Json::Num(muls as f64)),
                        ("plain_muls", Json::Num(plains as f64)),
                        ("adds", Json::Num(adds as f64)),
                        ("batches", Json::Num(batches as f64)),
                    ]),
                ),
                ("snapshot", snapshot.to_json()),
            ]))
        }
        other => Err(anyhow!("unknown request type '{other}'")),
    }
}

/// Blocking client for the wire protocol.
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl Client {
    pub fn connect(addr: &str) -> Result<Client> {
        let stream = TcpStream::connect(addr).with_context(|| format!("connecting {addr}"))?;
        stream.set_nodelay(true).ok();
        Ok(Client { reader: BufReader::new(stream.try_clone()?), writer: stream })
    }

    fn call(&mut self, req: Json) -> Result<Json> {
        self.writer.write_all(req.to_string_json().as_bytes())?;
        self.writer.write_all(b"\n")?;
        let mut line = String::new();
        self.reader.read_line(&mut line)?;
        let resp = Json::parse(line.trim()).context("malformed response")?;
        if resp.get("ok").and_then(|v| v.as_bool()) != Some(true) {
            let msg = resp
                .get("error")
                .and_then(|e| e.as_str())
                .unwrap_or("unknown server error");
            return Err(anyhow!("server error: {msg}"));
        }
        Ok(resp)
    }

    pub fn ping(&mut self) -> Result<()> {
        self.call(Json::obj(vec![("type", Json::str("ping"))])).map(|_| ())
    }

    pub fn submit(
        &mut self,
        data: &EncryptedDataset,
        cfg: &crate::els::encrypted::FitConfig,
        cd_updates: Option<usize>,
    ) -> Result<JobId> {
        let resp = self.call(Json::obj(vec![
            ("type", Json::str("submit")),
            ("data", proto::dataset_to_json(data)),
            ("cfg", proto::cfg_to_json(cfg, cd_updates)),
        ]))?;
        Ok(JobId(resp.req("id")?.as_u64().context("id")?))
    }

    pub fn status(&mut self, id: JobId) -> Result<String> {
        let resp = self.call(Json::obj(vec![
            ("type", Json::str("status")),
            ("id", Json::Num(id.0 as f64)),
        ]))?;
        Ok(resp.req("state")?.as_str().context("state")?.to_string())
    }

    /// Block until the job finishes and fetch the encrypted fit.
    pub fn result(&mut self, ctx: &crate::fhe::FvContext, id: JobId) -> Result<EncryptedFit> {
        let resp = self.call(Json::obj(vec![
            ("type", Json::str("result")),
            ("id", Json::Num(id.0 as f64)),
        ]))?;
        proto::fit_from_json(ctx, resp.req("fit")?)
    }

    pub fn metrics(&mut self) -> Result<String> {
        let resp = self.call(Json::obj(vec![("type", Json::str("metrics"))]))?;
        Ok(resp.req("summary")?.as_str().context("summary")?.to_string())
    }

    /// Fetch the server's unified [`MetricsSnapshot`] JSON document
    /// (schema `els-metrics-v1`) — the machine-readable counterpart of
    /// [`metrics`](Self::metrics).
    pub fn metrics_snapshot(&mut self) -> Result<Json> {
        let resp = self.call(Json::obj(vec![("type", Json::str("metrics"))]))?;
        Ok(resp.req("snapshot")?.clone())
    }
}
